"""repro.data subpackage."""
