"""Data pipeline: synthetic LM streams + the paper's morphological
analyzer as a first-class preprocessing operator.

`morph_lm_batches` is the integration point (DESIGN.md §4): a stream of
Arabic verb forms is encoded to character tokens while the batched JAX
stemmer produces per-word root ids — usable as auxiliary labels
(root-prediction heads) or for root-aware vocabulary reduction. The
stemmer runs at MWps throughput (see benchmarks/throughput.py), so it
never bottlenecks the input pipeline.
"""
from __future__ import annotations

import numpy as np

from repro.core import alphabet as ab
from repro.core import corpus as corpus_mod
from repro.core import stemmer


def synthetic_lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
                         effective_vocab: int | None = None, branching: int = 4):
    """Endless synthetic token batches (markov chain, learnable signal).

    effective_vocab restricts the emitted ids (< vocab) so small smoke
    models can visibly learn within tens of steps.
    """
    rng = np.random.default_rng(seed)
    ev = min(effective_vocab or vocab, vocab)
    # fixed bigram table so the LM example has signal to learn
    trans = rng.integers(0, ev, size=(ev, branching)).astype(np.int32)
    while True:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, ev, size=batch)
        for t in range(seq):
            choice = rng.integers(0, branching, size=batch)
            toks[:, t + 1] = trans[toks[:, t], choice]
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class MorphPreprocessor:
    """Batched root extraction as a pipeline operator.

    backend is any core.stemmer Compare backend ("sorted" / "dense" /
    "pallas" / "fused" — the last runs the single-launch stage 1-5
    megakernel, see kernels/stem_fused.py). For the fused backend,
    residency picks the megakernel's dictionary layout ("resident" /
    "streamed" / "auto"; "auto" streams production-size dictionaries
    past the VMEM budget — DESIGN.md §5.3).
    """

    def __init__(self, n_tri=2000, n_quad=200, backend="sorted", seed=0,
                 residency="auto"):
        self.rootdict = corpus_mod.build_dictionary(n_tri, n_quad, seed)
        self.arrays = stemmer.RootDictArrays.from_rootdict(self.rootdict)
        self.backend = backend
        self.residency = residency
        # root id table: sorted packed keys; id == searchsorted rank + 1
        keys = sorted(
            {ab.pack_key(r) for r in self.rootdict.tri}
            | {ab.pack_key(r) for r in self.rootdict.quad}
            | {ab.pack_key(r) for r in self.rootdict.bi})
        self._id_keys = np.asarray(keys, np.int64)  # sorted, 0 = none
        self.n_roots = len(keys) + 1

    def __call__(self, words: list[str]):
        """words -> (char_tokens int32[B,16], root_ids int32[B])."""
        enc = corpus_mod.encode_corpus(words)
        roots, _src = stemmer.stem_batch(enc, self.arrays,
                                         backend=self.backend,
                                         residency=self.residency)
        roots = np.asarray(roots).astype(np.int64)
        keys = ((roots[:, 0] * 64 + roots[:, 1]) * 64 + roots[:, 2]) * 64 + roots[:, 3]
        # vectorised key -> dense id: rank lookup in the sorted key table
        idx = np.searchsorted(self._id_keys, keys)
        idx_c = np.minimum(idx, len(self._id_keys) - 1)
        ids = np.where(self._id_keys[idx_c] == keys, idx_c + 1, 0).astype(np.int32)
        return enc, ids


def morph_lm_batches(batch_words: int, seq: int, seed: int = 0,
                     preproc: MorphPreprocessor | None = None):
    """Arabic char-level LM stream with root-id auxiliary labels.

    Words are conjugated verb forms (corpus.build_corpus); tokens are
    6-bit char codes (vocab = alphabet.N_CODES + separator); labels shift
    by one. Each chunk carries ONLY the root ids of the words whose
    characters actually appear in that chunk ("root_ids"), plus the
    half-open word-index span it covers ("word_span") — auxiliary
    root-prediction labels stay aligned with the chunk's content.
    """
    pre = preproc or MorphPreprocessor(seed=seed)
    rng = np.random.default_rng(seed)
    sep = ab.N_CODES  # word separator token
    vocab = ab.N_CODES + 1
    epoch = 0
    while True:
        words, _truths, _ = corpus_mod.build_corpus(
            n_words=batch_words, seed=seed + epoch)
        enc, root_ids = pre(words)
        stream, word_of = [], []
        for wi, row in enumerate(enc):
            for c in row:
                if c:
                    stream.append(int(c))
                    word_of.append(wi)
            stream.append(sep)
            word_of.append(wi)  # the separator still belongs to word wi
        n_tok = (len(stream) // (seq + 1)) * (seq + 1)
        toks = np.asarray(stream[:n_tok], np.int32).reshape(-1, seq + 1)
        spans = np.asarray(word_of[:n_tok], np.int32).reshape(-1, seq + 1)
        for i in range(toks.shape[0]):
            w0, w1 = int(spans[i, 0]), int(spans[i, -1]) + 1
            yield {
                "tokens": toks[i : i + 1, :-1],
                "labels": toks[i : i + 1, 1:].copy(),
                "vocab": vocab,
                "root_ids": root_ids[w0:w1],
                "word_span": (w0, w1),
            }
        epoch += 1
