"""Text serving: documents in, per-token (root, source, byte span) out.

``TextAnalysisWorkload`` moves the serving boundary from pre-packed
`[block_b, 16]` word tiles to raw text, without touching the machinery
underneath: it subclasses :class:`StemmerWorkload` and overrides only
``make_request`` — admission coalesces a request's documents into ONE
codepoint tile (single 0 separator between docs, bucketed to a pow2
multiple of ``char_block`` so traces stay bounded), runs the text
front-end (kernels/text_frontend.py by default) to get normalised word
rows + utf-8 byte spans, attributes each word to its document by span
offset, and hands the word rows to the *unchanged* PR 4-6 pipeline:
dispatch/retire ring, megabatching, ``data_devices`` sharding and
``persistent`` descriptor-ring launches all serve text requests exactly
as they serve word-tile requests. Results scatter back per document
through :meth:`TextRequest.analyses`.

The front end runs at admission (host-side tick), not inside the
stemmer launch: word counts are data-dependent, so the ring's fixed
[launch_b, 16] staging contract — the thing that keeps one jit trace —
needs the counts on the host anyway. The fully fused device-side chain
exists as ``ops.extract_roots_text`` for the batch path.

Crash safety (DESIGN.md §12) comes for free through the same
inheritance: the write-ahead journal stores a text submission as its
raw document list (the ``strs`` payload codec), so ``Engine.recover``
replays the *text*, re-running normalisation + segmentation through
``make_request`` — the front end is deterministic, so the recovered
word rows, spans and roots are bit-identical; ``pin_version`` (a
StemRequest field) re-pins the admitted lexicon exactly as on the
word-tile path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import alphabet as ab
from repro.core import textnorm as tn
from repro.serve.engine import StemmerWorkload, StemRequest

FRONTENDS = ("kernel", "reference", "host")


@dataclass
class TextRequest(StemRequest):
    """A document-batch request; words/roots/sources/dict_versions hold
    the flattened per-token state in document order (StemRequest fields),
    plus the text-level view needed to scatter results back per doc."""

    docs: list = field(default_factory=list)   # original documents
    doc_ids: np.ndarray = None                 # int32 [n] doc index per word
    spans: np.ndarray = None                   # int32 [n, 2] per-doc byte span
    n_bytes: int = 0                           # utf-8 bytes across docs

    def analyses(self) -> list[list[tuple[str, int, tuple[int, int]]]]:
        """Per-document [(root, source, (byte_start, byte_end))].

        A terminally failed request (quarantined / deadline / shed /
        cancelled — ``self.failure`` is set) holds zero-filled roots for
        its unserved words; reading analyses off it would silently
        return garbage, so it raises instead — check ``failure`` first.
        """
        if self.failure is not None:
            raise RuntimeError(
                f"request {self.rid} failed ({self.failure.code}:"
                f" {self.failure.detail}); no analyses to read")
        out: list[list] = [[] for _ in self.docs]
        for i in range(self.n_words):
            out[int(self.doc_ids[i])].append(
                (ab.decode_word(self.roots[i]), int(self.sources[i]),
                 (int(self.spans[i, 0]), int(self.spans[i, 1]))))
        return out


class TextAnalysisWorkload(StemmerWorkload):
    """StemmerWorkload whose public payload is text.

    frontend="kernel"     text_frontend_pallas + geometry pre-pass (default)
    frontend="reference"  pure-jnp textnorm.frontend_reference
    frontend="host"       python textnorm.analyze_text_py per document

    All three are bit-identical (parity-tested); the host path is the
    oracle the others are checked against in tests.
    """

    def __init__(self, store, *, char_block: int = 2048,
                 text_block_w: int = 128, frontend: str = "kernel", **kw):
        if frontend not in FRONTENDS:
            raise ValueError(f"unknown frontend {frontend!r}"
                             f" (choose from {FRONTENDS})")
        if char_block < 128:
            raise ValueError(f"char_block must be >= 128, got {char_block}")
        super().__init__(store, **kw)
        self.char_block = char_block
        self.text_block_w = text_block_w
        self.frontend = frontend

    # -- admission: text -> word rows --------------------------------------
    def _char_bucket(self, n: int) -> int:
        """Smallest char_block * 2^k >= n (pow2 buckets bound the number
        of front-end jit traces a ragged document stream replays)."""
        b = self.char_block
        while b < n:
            b *= 2
        return b

    def make_request(self, rid: int, docs, **opts) -> TextRequest:
        if opts:
            raise ValueError(f"unknown text request options: {sorted(opts)}")
        if isinstance(docs, str):
            docs = [docs]
        docs = list(docs)
        for d in docs:
            if not isinstance(d, str):
                raise ValueError(
                    "text workload takes str documents, got"
                    f" {type(d).__name__}")
        chars, _char_off, byte_off = tn.coalesce_docs(docs)
        n_bytes = sum(len(d.encode("utf-8")) for d in docs)
        if self.frontend == "host":
            words, spans, doc_ids = self._frontend_host(docs)
        else:
            words, spans, doc_ids = self._frontend_device(chars, byte_off)
        n = words.shape[0]
        return TextRequest(
            rid, np.ascontiguousarray(words, np.int32),
            roots=np.zeros((n, 4), np.int32),
            sources=np.zeros(n, np.int32),
            dict_versions=np.zeros(n, np.int32),
            docs=docs, doc_ids=doc_ids, spans=spans, n_bytes=n_bytes)

    def _frontend_host(self, docs):
        parts = [tn.analyze_text_py(d) for d in docs]
        words = (np.concatenate([w for w, _ in parts])
                 if parts else np.zeros((0, ab.MAXLEN), np.int32))
        spans = (np.concatenate([s for _, s in parts])
                 if parts else np.zeros((0, 2), np.int32))
        doc_ids = (np.concatenate(
            [np.full(w.shape[0], i, np.int32)
             for i, (w, _) in enumerate(parts)])
            if parts else np.zeros(0, np.int32))
        return words, spans, doc_ids

    def _frontend_device(self, chars, byte_off):
        from repro.kernels import ops  # lazy: keep engine import light

        tile = np.zeros(self._char_bucket(max(chars.shape[0], 1)), np.int32)
        tile[:chars.shape[0]] = chars
        if self.frontend == "kernel":
            words_d, spans_d, nw = ops.text_to_words(
                tile, block_w=self.text_block_w, interpret=self.interpret)
        else:
            words_d, geo = tn.frontend_reference(
                tile, block_w=self.text_block_w)
            spans_d, nw = geo.spans, geo.n_words
        n = int(nw)
        words = np.asarray(words_d)[:n]
        spans_abs = np.asarray(spans_d)[:n].astype(np.int64)
        if byte_off.size:
            # word -> owning doc: the last doc whose byte offset is <=
            # the word's absolute byte start (separators add one byte)
            doc_ids = (np.searchsorted(byte_off, spans_abs[:, 0],
                                       side="right") - 1).astype(np.int32)
            spans = (spans_abs - byte_off[doc_ids][:, None]).astype(np.int32)
        else:
            doc_ids = np.zeros(0, np.int32)
            spans = spans_abs.astype(np.int32)
        return words, spans, doc_ids
