"""Write-ahead request journal: the engine's crash-safety spine.

Everything the engine accepts is durable before it is served, and
everything it serves is marked durable after — so a killed process
loses no accepted work, and a restarted one re-serves exactly the
unfinished suffix (DESIGN.md §12).

Record format — one record per line, sha-disciplined like the index
checkpoints (content hash verified before anything is trusted):

    <sha16> <canonical-json>\\n

where ``sha16 = sha256(json_utf8)[:16]``. Two record kinds:

  admit   {"kind": "admit", "rid", "payload": <codec>, "digest",
           "deadline_s", "dict_version", "opts"}
          appended by ``Engine.submit`` *before* the request enters the
          queue. ``payload`` is the submitted payload itself (encoded
          word tiles, raw strings, or document lists — replay needs the
          bytes, not just a fingerprint); ``digest`` is its content
          hash, re-verified at replay; ``dict_version`` is the store
          version current at admission, which recovery re-pins so the
          request is served under the exact lexicon it was accepted for.
  retire  {"kind": "retire", "rid", "digest", "failure"}
          appended when the request reaches the finished table —
          ``digest`` hashes the response arrays (None for terminal
          failures, whose ``failure`` carries the FailureInfo code).

Durability: every append is written + flushed to the OS (surviving
process death); ``fsync_every`` batches the fsync that also survives
host power loss. A *torn tail* — the trailing record failing its
checksum or framing, what a crash mid-write leaves — is truncated by
:meth:`Journal.read`; records are trusted only up to the first bad one
(standard WAL semantics: ordering after a tear is unprovable).

Replay is bit-identical by construction: the megakernel's per-word
output is independent of tile packing (parity-tested across every
launch path), so re-running the unfinished admits through the normal
FIFO-coalescing path reproduces the uninterrupted run's bytes even
though the restarted engine coalesces different tile boundaries.
Partially served requests are re-served from word 0 — re-doing a
deterministic launch is cheaper than journaling per-tile scatter state.
"""
from __future__ import annotations

import base64
import hashlib
import json
import os
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class RecoveryReport:
    """What Engine.recover found in the journal: the rids it re-queued,
    how many were already retired (skipped), and the torn-tail bytes it
    truncated."""

    replayed: list = field(default_factory=list)
    already_retired: int = 0
    dropped_bytes: int = 0


class JournalError(RuntimeError):
    """A journal record that parsed but cannot be trusted (payload
    digest mismatch, undecodable payload codec)."""


def _sha16(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()[:16]


# ---------------------------------------------------------------------------
# payload codec: what Engine.submit accepts must round-trip through JSON
# ---------------------------------------------------------------------------
def encode_payload(payload) -> dict:
    """Submitted payload -> JSON-safe codec dict (ndarray via base64,
    strings and homogeneous str/int lists verbatim)."""
    if isinstance(payload, np.ndarray):
        a = np.ascontiguousarray(payload)
        return {"t": "nd", "dtype": str(a.dtype), "shape": list(a.shape),
                "b64": base64.b64encode(a.tobytes()).decode("ascii")}
    if isinstance(payload, str):
        return {"t": "str", "s": payload}
    if isinstance(payload, (list, tuple)):
        items = list(payload)
        if all(isinstance(x, str) for x in items):
            return {"t": "strs", "items": items}
        if all(isinstance(x, (int, np.integer)) for x in items):
            return {"t": "ints", "items": [int(x) for x in items]}
    raise TypeError(
        f"journal cannot encode payload of type {type(payload).__name__}"
        " (want ndarray, str, or a homogeneous list of str/int)")


def decode_payload(enc: dict):
    t = enc.get("t")
    if t == "nd":
        a = np.frombuffer(base64.b64decode(enc["b64"]),
                          dtype=np.dtype(enc["dtype"]))
        return a.reshape(enc["shape"]).copy()
    if t == "str":
        return enc["s"]
    if t == "strs":
        return list(enc["items"])
    if t == "ints":
        return [int(x) for x in enc["items"]]
    raise JournalError(f"unknown payload codec {t!r}")


def payload_digest(payload) -> str:
    """Content hash of a payload, stable across encode/decode."""
    enc = encode_payload(payload)
    return _sha16(json.dumps(enc, sort_keys=True,
                             separators=(",", ":")).encode())


def response_digest(req) -> str | None:
    """Content hash of a finished request's response: (roots, sources)
    for stemmer/text requests, the token list for LM requests — the
    integrity anchor crash-restart tests compare against."""
    roots = getattr(req, "roots", None)
    if roots is not None:
        return _sha16(np.ascontiguousarray(roots).tobytes()
                      + np.ascontiguousarray(req.sources).tobytes())
    toks = getattr(req, "tokens_out", None)
    if toks is not None:
        return _sha16(json.dumps([int(t) for t in toks]).encode())
    return None


# ---------------------------------------------------------------------------
# the journal
# ---------------------------------------------------------------------------
class Journal:
    """Append-only, checksummed, batch-fsynced request log."""

    def __init__(self, path, *, fsync_every: int = 32, injector=None):
        if fsync_every < 1:
            raise ValueError(f"fsync_every must be >= 1, got {fsync_every}")
        self.path = str(path)
        self.fsync_every = fsync_every
        self.injector = injector
        self.appended = 0
        self._since_sync = 0
        self._f = open(self.path, "ab")

    # -- writer side -------------------------------------------------------
    def _append(self, rec: dict) -> None:
        body = json.dumps(rec, sort_keys=True, separators=(",", ":"))
        line = f"{_sha16(body.encode())} {body}\n".encode()
        self._f.write(line)
        self._f.flush()                 # survives process death
        self.appended += 1
        self._since_sync += 1
        if self._since_sync >= self.fsync_every:
            os.fsync(self._f.fileno())  # survives power loss, batched
            self._since_sync = 0
        if self.injector is not None:
            self.injector.on_journal(self.path, len(line))

    def admit(self, rid: int, payload, *, deadline_s: float | None = None,
              dict_version: int | None = None, opts: dict | None = None):
        enc = encode_payload(payload)
        self._append({
            "kind": "admit", "rid": int(rid), "payload": enc,
            "digest": _sha16(json.dumps(enc, sort_keys=True,
                                        separators=(",", ":")).encode()),
            "deadline_s": deadline_s,
            "dict_version": (None if dict_version is None
                             else int(dict_version)),
            "opts": dict(opts or {})})

    def retire(self, req) -> None:
        failure = getattr(req, "failure", None)
        self._append({
            "kind": "retire", "rid": int(req.rid),
            "digest": response_digest(req) if failure is None else None,
            "failure": None if failure is None else failure.code})

    def sync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())
        self._since_sync = 0

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    # -- reader side -------------------------------------------------------
    @staticmethod
    def read(path, *, truncate: bool = True) -> tuple[list[dict], int]:
        """Parse a journal, stopping at the first torn/corrupt record;
        returns (records, dropped_bytes). With ``truncate`` (default)
        the file is physically cut back to the last good record so a
        recovered engine appends onto a clean tail."""
        path = str(path)
        if not os.path.exists(path):
            return [], 0
        with open(path, "rb") as f:
            data = f.read()
        records, off, good = [], 0, 0
        while off < len(data):
            nl = data.find(b"\n", off)
            if nl < 0:
                break                   # unterminated (torn) tail
            line = data[off:nl]
            try:
                sha, body = line.split(b" ", 1)
                if sha.decode("ascii") != _sha16(body):
                    break
                rec = json.loads(body.decode("utf-8"))
            except Exception:
                break
            records.append(rec)
            off = good = nl + 1
        dropped = len(data) - good
        if dropped and truncate:
            with open(path, "r+b") as f:
                f.truncate(good)
        return records, dropped


def unfinished_admits(records: list[dict]) -> list[dict]:
    """Admit records with no matching retire, in journal (= rid) order —
    exactly the work a recovered engine owes."""
    retired = {int(r["rid"]) for r in records if r.get("kind") == "retire"}
    return [r for r in records
            if r.get("kind") == "admit" and int(r["rid"]) not in retired]
