"""Serving health: the structured engine event stream and the
graceful-degradation ladder.

Every notable serving incident — a terminal :class:`FailureInfo`, a
retry/bisection/quarantine, a checksum or flag mismatch, a watchdog
stall, a device loss, a ladder transition, a warm restart — is emitted
as an :class:`EngineEvent` into a shared :class:`EventLog` that
``Engine.events()`` exposes, so operators (and the chaos matrix) read
one stream instead of grepping counters scattered across the workload.

:class:`DegradationPolicy` closes the loop: observed once per engine
step, it walks a precomputed ladder of :class:`ServingMode` rungs

    persistent -> megabatch -> per-tile
    resident dictionary -> streamed
    data_devices = N -> N/2 -> ... -> 1

downshifting one rung after ``down_after`` consecutive unhealthy steps
(new faults, or queue length past ``queue_high``) and upshifting one
rung after ``up_after`` consecutive healthy steps — classic hysteresis,
so a single fault burst cannot make the ladder oscillate. A device loss
is special-cased: it downshifts immediately to the first rung with
fewer data devices and *caps* the ladder there (a lost device does not
come back). Every rung serves bit-identically (the megakernel paths are
parity-tested against each other), so transitions change throughput and
footprint, never results; the workload applies a requested mode only at
a tick whose ring is empty, so in-flight launches keep the geometry
they dispatched with.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field


@dataclass(frozen=True)
class EngineEvent:
    """One structured serving incident: ``kind`` + monotonic timestamp +
    free-form payload (rids, counts, rung labels...)."""

    kind: str
    t: float
    data: dict = field(default_factory=dict)


class EventLog:
    """Bounded in-memory event stream shared by engine, workload and
    policy; ``maxlen`` keeps a long-lived server's log from growing
    without bound (oldest events drop first)."""

    def __init__(self, maxlen: int = 4096):
        self._events: collections.deque = collections.deque(maxlen=maxlen)

    def emit(self, kind: str, **data) -> EngineEvent:
        ev = EngineEvent(kind, time.monotonic(), data)
        self._events.append(ev)
        return ev

    def snapshot(self) -> list[EngineEvent]:
        return list(self._events)

    def drain(self) -> list[EngineEvent]:
        out = list(self._events)
        self._events.clear()
        return out

    def count(self, kind: str) -> int:
        return sum(e.kind == kind for e in self._events)


@dataclass(frozen=True)
class ServingMode:
    """One ladder rung: the launch geometry + dictionary residency the
    workload should serve with. ``residency=None`` keeps the residency
    each published handle pinned; "streamed" overrides resident handles
    onto the HBM tile-stream path (smaller VMEM footprint)."""

    label: str
    persistent: bool = False
    megabatch_tiles: int = 1
    data_devices: int = 1
    residency: str | None = None


def build_ladder(*, persistent: bool = False, megabatch_tiles: int = 1,
                 data_devices: int = 1,
                 resident_dict: bool = True) -> tuple[ServingMode, ...]:
    """The degradation ladder for a workload configuration, top rung
    first (the configured mode) down to the most conservative one.

    Rung order mirrors blast radius: drop the persistent descriptor
    ring first (a wedged kernel is the sharpest failure), then megabatch
    depth, then force the dictionary onto the streamed path, then shed
    data devices (halving; every count shard_batch pads for serves
    bit-identically).
    """
    rungs: list[ServingMode] = []
    if persistent:
        rungs.append(ServingMode("persistent", True, megabatch_tiles,
                                 data_devices))
    if megabatch_tiles > 1:
        rungs.append(ServingMode(f"megabatch x{megabatch_tiles}", False,
                                 megabatch_tiles, data_devices))
    rungs.append(ServingMode("per-tile", False, 1, data_devices))
    if resident_dict:
        rungs.append(ServingMode("streamed-dict", False, 1, data_devices,
                                 "streamed"))
    from repro.dist.shard_batch import device_downshift_ladder

    override = "streamed" if resident_dict else None
    for d in device_downshift_ladder(data_devices):
        if d < data_devices:
            rungs.append(ServingMode(f"devices-{d}", False, 1, d, override))
    return tuple(rungs)


class DegradationPolicy:
    """Hysteresis controller over the ladder; observed once per engine
    step (``Engine`` calls :meth:`observe` at the end of ``step()``).

    A step is *unhealthy* when the workload's fault counters advanced
    since the last observation or the queue length is at/past
    ``queue_high``; ``down_after`` consecutive unhealthy steps downshift
    one rung, ``up_after`` consecutive healthy steps upshift one. Device
    losses bypass the hysteresis (see module docstring). All transitions
    are emitted as ``degrade``/``upshift`` events and recorded in
    ``transitions``.
    """

    FAULT_COUNTERS = ("retries_total", "checksum_failures", "timeouts",
                      "watchdog_stalls", "device_losses")

    def __init__(self, *, queue_high: int | None = None, down_after: int = 2,
                 up_after: int = 8, rungs=None):
        if queue_high is not None and queue_high < 1:
            raise ValueError(f"queue_high must be >= 1, got {queue_high}")
        if down_after < 1 or up_after < 1:
            raise ValueError("down_after and up_after must be >= 1")
        self.queue_high = queue_high
        self.down_after = down_after
        self.up_after = up_after
        self.rungs = tuple(rungs) if rungs is not None else None
        self.level = 0
        self.transitions: list[tuple[str, str, str]] = []  # (from, to, why)
        self._unhealthy = 0
        self._healthy = 0
        self._last: dict | None = None
        self._workload = None
        self._events: EventLog | None = None
        self._device_cap: int | None = None

    # -- wiring (Engine calls attach at construction) ----------------------
    def attach(self, workload, events: EventLog) -> None:
        if not hasattr(workload, "request_mode"):
            raise ValueError(
                "DegradationPolicy needs a workload with mode transitions"
                f" (request_mode); {type(workload).__name__} has none")
        self._workload = workload
        self._events = events
        if self.rungs is None:
            store = getattr(workload, "store", None)
            resident = (store is not None
                        and store.acquire().handle.residency == "resident")
            self.rungs = build_ladder(
                persistent=workload.persistent,
                megabatch_tiles=workload.megabatch_tiles,
                data_devices=workload.data_devices,
                resident_dict=resident)
        self._last = self._counters()

    @property
    def mode(self) -> ServingMode:
        return self.rungs[self.level]

    def _counters(self) -> dict:
        return {c: getattr(self._workload, c, 0)
                for c in self.FAULT_COUNTERS}

    # -- the control loop --------------------------------------------------
    def observe(self, engine) -> None:
        if self._workload is None:
            raise RuntimeError("policy not attached to a workload")
        cur = self._counters()
        new_faults = sum(cur[c] - self._last[c] for c in self.FAULT_COUNTERS)
        lost = cur["device_losses"] - self._last["device_losses"]
        self._last = cur
        if lost > 0:
            self._on_device_loss()
            return
        unhealthy = (new_faults > 0
                     or (self.queue_high is not None
                         and len(engine.queue) >= self.queue_high))
        if unhealthy:
            self._healthy = 0
            self._unhealthy += 1
            if (self._unhealthy >= self.down_after
                    and self.level + 1 < len(self.rungs)):
                self._shift(self.level + 1,
                            "faults" if new_faults else "queue")
                self._unhealthy = 0
        else:
            self._unhealthy = 0
            self._healthy += 1
            if self._healthy >= self.up_after and self.level > 0:
                target = self.level - 1
                if (self._device_cap is None
                        or self.rungs[target].data_devices
                        <= self._device_cap):
                    self._shift(target, "healthy")
                self._healthy = 0

    def _on_device_loss(self) -> None:
        """Immediate downshift to the first rung with fewer data devices,
        capping the ladder there — a lost device does not come back, so
        upshift never climbs above the cap."""
        d = self.mode.data_devices
        cap = next((r.data_devices for r in self.rungs
                    if r.data_devices < d), 1)
        self._device_cap = (cap if self._device_cap is None
                            else min(self._device_cap, cap))
        target = next((i for i in range(self.level + 1, len(self.rungs))
                       if self.rungs[i].data_devices <= cap), None)
        if target is not None:
            self._shift(target, "device_loss")
        self._unhealthy = self._healthy = 0

    def _shift(self, target: int, reason: str) -> None:
        old, new = self.rungs[self.level], self.rungs[target]
        kind = "degrade" if target > self.level else "upshift"
        self.level = target
        self._workload.request_mode(new)
        self.transitions.append((old.label, new.label, reason))
        if self._events is not None:
            self._events.emit(kind, reason=reason,
                              **{"from": old.label, "to": new.label})
