"""repro.serve subpackage: workload-agnostic continuous batching.

Engine (scheduler) x Workload (LMDecodeWorkload | StemmerWorkload) +
DictStore (versioned hot-swappable stemmer dictionaries). ServeEngine
is the back-compat LM facade.
"""
from repro.serve.dict_store import DictStore, DictVersion
from repro.serve.engine import (DrainReport, Engine, EngineUndrained,
                                InflightTile, LMDecodeWorkload, Request,
                                ServeEngine, StemRequest, StemmerWorkload,
                                Workload)
from repro.serve.text import TextAnalysisWorkload, TextRequest

__all__ = [
    "DictStore", "DictVersion", "DrainReport", "Engine", "EngineUndrained",
    "InflightTile", "LMDecodeWorkload", "Request", "ServeEngine",
    "StemRequest", "StemmerWorkload", "TextAnalysisWorkload", "TextRequest",
    "Workload",
]
