"""repro.serve subpackage: workload-agnostic continuous batching.

Engine (scheduler) x Workload (LMDecodeWorkload | StemmerWorkload) +
DictStore (versioned hot-swappable stemmer dictionaries). ServeEngine
is the back-compat LM facade. ``faults`` supplies the deterministic
fault-injection harness (FaultPlan/FaultInjector) and the structured
FailureInfo that terminally failed requests carry.
"""
from repro.serve.dict_store import (DictStore, DictValidationError,
                                    DictVersion, validate_handle)
from repro.serve.engine import (DrainReport, Engine, EngineUndrained,
                                InflightTile, LMDecodeWorkload, QueueFull,
                                Request, ServeEngine, StemRequest,
                                StemmerWorkload, Workload)
from repro.serve.faults import (FailureInfo, FaultInjector, FaultPlan,
                                FaultSpec, InjectedFault)
from repro.serve.text import TextAnalysisWorkload, TextRequest

__all__ = [
    "DictStore", "DictValidationError", "DictVersion", "DrainReport",
    "Engine", "EngineUndrained", "FailureInfo", "FaultInjector",
    "FaultPlan", "FaultSpec", "InflightTile", "InjectedFault",
    "LMDecodeWorkload", "QueueFull", "Request", "ServeEngine",
    "StemRequest", "StemmerWorkload", "TextAnalysisWorkload", "TextRequest",
    "Workload", "validate_handle",
]
