"""repro.serve subpackage."""
