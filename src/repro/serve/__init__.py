"""repro.serve subpackage: workload-agnostic continuous batching.

Engine (scheduler) x Workload (LMDecodeWorkload | StemmerWorkload) +
DictStore (versioned hot-swappable stemmer dictionaries). ServeEngine
is the back-compat LM facade. ``faults`` supplies the deterministic
fault-injection harness (FaultPlan/FaultInjector) and the structured
FailureInfo that terminally failed requests carry. ``journal`` is the
write-ahead request log behind ``Engine.recover`` (crash-safe warm
restart); ``health`` is the structured event stream plus the
graceful-degradation ladder (DESIGN.md §12).
"""
from repro.serve.dict_store import (DictSnapshotError, DictStore,
                                    DictValidationError, DictVersion,
                                    validate_handle)
from repro.serve.engine import (DrainReport, Engine, EngineUndrained,
                                InflightTile, LMDecodeWorkload, QueueFull,
                                Request, ServeEngine, StemRequest,
                                StemmerWorkload, Workload)
from repro.serve.faults import (DeviceLost, FailureInfo, FaultInjector,
                                FaultPlan, FaultSpec, InjectedFault)
from repro.serve.health import (DegradationPolicy, EngineEvent, EventLog,
                                ServingMode, build_ladder)
from repro.serve.journal import (Journal, JournalError, RecoveryReport,
                                 payload_digest, response_digest)
from repro.serve.text import TextAnalysisWorkload, TextRequest

__all__ = [
    "DegradationPolicy", "DeviceLost", "DictSnapshotError", "DictStore",
    "DictValidationError", "DictVersion", "DrainReport", "Engine",
    "EngineEvent", "EngineUndrained", "EventLog", "FailureInfo",
    "FaultInjector", "FaultPlan", "FaultSpec", "InflightTile",
    "InjectedFault", "Journal", "JournalError", "LMDecodeWorkload",
    "QueueFull", "RecoveryReport", "Request", "ServeEngine",
    "ServingMode", "StemRequest", "StemmerWorkload",
    "TextAnalysisWorkload", "TextRequest", "Workload", "build_ladder",
    "payload_digest", "response_digest", "validate_handle",
]
