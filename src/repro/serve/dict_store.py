"""Versioned root-dictionary store for serving-time lexicon hot swaps.

The streamed megakernel layout (DESIGN.md §5.3) keeps dictionary tiles
in HBM, so swapping the lexicon between tile launches costs a device
upload, not an engine restart. This module supplies the serving-side
contract for that swap:

  publish(arrays)  upload a new dictionary as the next monotonically
                   increasing version; it becomes current atomically and
                   is picked up by the *next* tile launch
  publish_delta()  the same, but as insert/remove key lists sorted-merged
                   against the current version — untouched tables keep
                   their device arrays instead of re-uploading
  acquire()        snapshot the current version; a dispatch holds its
                   snapshot for the whole tile launch (and through
                   retire), so a concurrent publish never changes — or
                   relabels — a tile in flight

Each version wraps its arrays in a ``core.stemmer.ResolvedRootDict``
handle at publish time: residency="auto" is resolved against the VMEM
budget once (scoped by ``infix`` to the tables the sweep loads), so a
swap whose arrays keep their shapes replays the megakernel's cached jit
trace (no re-trace on the serving hot path). Constructing the store
with ``dict_block_r`` additionally pins the streamed layout's
``DictTileSet`` — the padded `[tri | quad | bi]` tile stream plus the
per-tile boundary tables the tile-visit pre-pass needs — into every
published handle, so serving launches never re-pad or re-concatenate
the dictionary per call and hot swaps keep the cached trace.
Responses record the version(s) that served them (StemRequest.dict_
versions), and ``get(version)`` resolves any published version back to
its arrays, so served roots stay auditable after further swaps.

Publishes are *two-phase* (DESIGN.md "Failure model & recovery"):
phase 1 packs + resolves the handle and validates the layout every
kernel path assumes — 1-D int32 tables, strictly sorted unique packed
24-bit keys (or the single ``[-1]`` empty-table sentinel), and, when a
streamed ``DictTileSet`` is prebuilt, tile-consistent sentinel-padded
boundary tables — raising :class:`DictValidationError` with the store
untouched; phase 2 is the atomic version bump. A ``FaultInjector``
passed at construction can reject between the phases (site
``publish``), proving no partial state ever lands. ``rollback(v)``
re-installs a kept historical version's handle as a NEW monotone
version — the recovery path when a published lexicon turns out bad
downstream of validation.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from dataclasses import dataclass

import numpy as np

from repro.core import alphabet as ab
from repro.core import pyref
from repro.core import stemmer as core_stemmer

TABLES = ("tri", "quad", "bi")


class DictValidationError(ValueError):
    """A publish failed phase-1 layout validation; nothing was installed."""


class DictSnapshotError(RuntimeError):
    """A catalog snapshot failed its content-hash verification."""


def _validate_table(name: str, arr) -> None:
    a = np.asarray(arr)
    if a.ndim != 1 or a.dtype != np.int32:
        raise DictValidationError(
            f"{name}: expected 1-D int32 table, got shape {a.shape}"
            f" dtype {a.dtype}")
    if a.size == 0:
        raise DictValidationError(
            f"{name}: empty table must be the [-1] sentinel, not size 0")
    if a.size == 1 and a[0] == -1:
        return                          # the empty-table sentinel
    if int(a.min()) < 0:
        raise DictValidationError(
            f"{name}: negative key {int(a.min())} (the -1 sentinel is only"
            " legal as a whole single-element table)")
    if int(a.max()) >= (1 << 24):
        raise DictValidationError(
            f"{name}: key {int(a.max())} outside the packed 24-bit range")
    d = np.diff(a)
    if d.size and int(d.min()) <= 0:
        at = int(np.argmin(d))
        raise DictValidationError(
            f"{name}: not strictly sorted/unique at index {at}"
            f" ({int(a[at])} -> {int(a[at + 1])})")


def validate_handle(handle: core_stemmer.ResolvedRootDict) -> None:
    """Phase-1 publish validation: every invariant the megakernel paths
    assume about a resolved dictionary handle.

    Raw tables must be sorted/unique packed keys (binary search and
    sorted-merge deltas both break silently otherwise). A prebuilt
    streamed tile set must be shape-consistent with ``dict_block_r`` and
    its boundary tables must equal the tile stream's first/last lanes —
    the sentinel-padded pow2-per-tile layout the tile-visit pre-pass
    range-rejects against.
    """
    from repro.kernels import stem_match as sm  # lazy, kernels need core

    for name in TABLES:
        _validate_table(name, getattr(handle.arrays, name))
    tiles = handle.tiles
    if tiles is None:
        return
    stream = np.asarray(tiles.stream)
    n_tiles = sum(tiles.counts)
    if stream.shape != (n_tiles * tiles.dict_block_r, sm.LANE):
        raise DictValidationError(
            f"tile stream shape {stream.shape} != "
            f"({n_tiles} tiles x {tiles.dict_block_r} rows, {sm.LANE})")
    flat = stream.reshape(n_tiles, -1)
    if np.diff(flat, axis=1).min(initial=0) < 0:
        raise DictValidationError(
            "tile stream has an internally unsorted tile (sentinel"
            " padding must keep every tile ascending)")
    mins, maxs = np.asarray(tiles.mins), np.asarray(tiles.maxs)
    if (mins.shape != (n_tiles,) or maxs.shape != (n_tiles,)
            or not np.array_equal(mins, flat[:, 0])
            or not np.array_equal(maxs, flat[:, -1])):
        raise DictValidationError(
            "tile boundary tables diverge from the tile stream's"
            " first/last lanes")


def _sorted_member(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Membership of sorted ``needles`` in sorted ``haystack`` via one
    searchsorted pass (no re-sort, unlike np.isin/setdiff1d)."""
    if not haystack.size:
        return np.zeros(needles.shape, bool)
    at = np.minimum(np.searchsorted(haystack, needles), haystack.size - 1)
    return haystack[at] == needles


def _delta_keys(spec) -> np.ndarray:
    """Delta key list -> sorted unique packed int32 keys. Raw root
    strings encode through the alphabet (pack_key takes dense *codes*,
    not characters); packed ints pass through."""
    if spec is None:
        return np.zeros(0, np.int32)
    keys = [ab.pack_key(ab.encode_word(k)) if isinstance(k, str) else int(k)
            for k in spec]
    return np.unique(np.asarray(keys, np.int32)) if keys else np.zeros(0, np.int32)


@dataclass(frozen=True)
class DictVersion:
    """One published dictionary: immutable (version, resolved handle)."""

    version: int
    handle: core_stemmer.ResolvedRootDict

    @property
    def arrays(self) -> core_stemmer.RootDictArrays:
        return self.handle.arrays

    @property
    def n_keys(self) -> int:
        return self.handle.n_keys


class DictStore:
    """Versioned RootDictArrays with publish/acquire semantics.

    Versions start at 0 (the constructor publishes the initial
    dictionary) and only ever grow. ``keep_history=False`` drops
    superseded versions on publish for long-lived servers that don't
    need ``get()`` on old versions.
    """

    def __init__(self, arrays, *, residency: str = "auto",
                 keep_history: bool = True, infix: bool = True,
                 dict_block_r: int | None = None, injector=None):
        self._lock = threading.Lock()       # guards the version table
        self._pub_lock = threading.Lock()   # serialises publishers
        self._residency = residency
        self._infix = infix
        self._dict_block_r = dict_block_r
        self._keep_history = keep_history
        self._versions: dict[int, DictVersion] = {}
        self._current: DictVersion | None = None
        self._next_version = 0
        self._injector = None
        self.publish(arrays)                # the seed is never injected:
        self._injector = injector           # a store must construct usable

    def _install(self, handle: core_stemmer.ResolvedRootDict) -> int:
        with self._lock:
            version = self._next_version
            self._next_version += 1
            dv = DictVersion(version, handle)
            if not self._keep_history:
                self._versions.clear()
            self._versions[version] = dv
            self._current = dv
        return version

    def _prepare(self, handle) -> core_stemmer.ResolvedRootDict:
        """Phase 1 of a publish: validate + (optionally) inject. No store
        state changes here — a raise leaves the current version serving."""
        validate_handle(handle)
        if self._injector is not None:
            self._injector.on_publish()
        return handle

    def publish(self, arrays, *, validate: bool = True) -> int:
        """Upload a new lexicon; returns its version number.

        Accepts packed RootDictArrays (or an already-resolved handle) or
        a raw pyref.RootDict, which is packed here. Two-phase: the
        resolved handle is validated first (DictValidationError leaves
        the store untouched), then installed — the new version becomes
        current atomically; in-flight ticks keep the snapshot they
        acquired. ``validate=False`` skips phase 1 for trusted bulk
        republishes.
        """
        with self._pub_lock:
            if isinstance(arrays, pyref.RootDict):
                arrays = core_stemmer.RootDictArrays.from_rootdict(arrays)
            handle = core_stemmer.resolve_dict(
                arrays, residency=self._residency, infix=self._infix,
                dict_block_r=self._dict_block_r)
            if validate:
                self._prepare(handle)
            return self._install(handle)

    def rollback(self, version: int) -> int:
        """Re-install a previously published version's handle as a NEW
        monotone version; returns the new version number.

        The recovery path when a validated publish turns out bad
        downstream (wrong roots in production): versions never move
        backwards — in-flight tiles keep serving the version they
        pinned — but the *next* dispatch acquires the restored lexicon.
        Requires ``keep_history=True`` (raises KeyError otherwise).
        """
        with self._pub_lock:
            dv = self.get(version)
            return self._install(dv.handle)

    def publish_delta(self, insert=None, remove=None) -> int:
        """Publish the next version as a sorted-merge delta against the
        current one; returns the new version number.

        ``insert`` / ``remove`` map table names ("tri" / "quad" / "bi")
        to key lists — packed int32 keys or raw root strings (encoded
        and packed through the alphabet). Only the touched tables are
        merged on the host and re-uploaded; untouched tables share the
        version's device arrays, so for large lexicons a small delta
        costs O(delta + touched table) instead of a whole-lexicon
        re-upload (the swap-latency rows in
        benchmarks/serve_throughput.py measure the difference).

        Removing a key that is not present raises ValueError (a delta
        that doesn't apply cleanly is a caller bug, not a no-op), as
        does a key appearing in both lists for the same table. Inserting
        an already-present key is idempotent.
        """
        insert = dict(insert or {})
        remove = dict(remove or {})
        unknown = (set(insert) | set(remove)) - set(TABLES)
        if unknown:
            raise ValueError(f"unknown dictionary tables: {sorted(unknown)}"
                             f" (want subset of {TABLES})")
        import jax.numpy as jnp

        with self._pub_lock:
            cur = self.acquire().arrays
            merged = {}
            for name in TABLES:
                ins = _delta_keys(insert.get(name))
                rem = _delta_keys(remove.get(name))
                old = getattr(cur, name)
                if not ins.size and not rem.size:
                    merged[name] = old      # untouched: same device buffer
                    continue
                both = np.intersect1d(ins, rem)
                if both.size:
                    raise ValueError(
                        f"{name}: keys {both.tolist()} appear in both"
                        " insert and remove")
                host = np.asarray(old)
                host = host[host >= 0]      # drop the empty-table sentinel
                # both sides are sorted: one searchsorted pass per list
                # (no re-sort of the table, unlike union1d/setdiff1d)
                if rem.size:
                    found = _sorted_member(host, rem)
                    if not found.all():
                        raise ValueError(
                            f"{name}: cannot remove absent keys"
                            f" {rem[~found].tolist()}")
                    keep = np.ones(host.size, bool)
                    keep[np.searchsorted(host, rem)] = False
                    host = host[keep]
                if ins.size:
                    ins = ins[~_sorted_member(host, ins)]  # idempotent
                    host = np.insert(host, np.searchsorted(host, ins), ins)
                out = host.astype(np.int32)
                if not out.size:
                    out = np.asarray([-1], np.int32)  # empty-table sentinel
                merged[name] = jnp.asarray(out)
            arrays = core_stemmer.RootDictArrays(**merged)
            handle = core_stemmer.resolve_dict(
                arrays, residency=self._residency, infix=self._infix,
                dict_block_r=self._dict_block_r)
            self._prepare(handle)       # two-phase, same as publish()
            return self._install(handle)

    def acquire(self) -> DictVersion:
        """Snapshot the current version (hold it for a whole tile launch)."""
        with self._lock:
            return self._current

    def get(self, version: int) -> DictVersion:
        """Resolve a previously published version (audit / parity checks)."""
        with self._lock:
            try:
                return self._versions[version]
            except KeyError:
                raise KeyError(
                    f"dict version {version} not in store (published so far:"
                    f" {self._next_version}, keep_history="
                    f"{self._keep_history})") from None

    @property
    def version(self) -> int:
        """Version number of the current dictionary."""
        with self._lock:
            return self._current.version

    # -- crash safety (DESIGN.md §12) --------------------------------------
    def snapshot(self, path) -> str:
        """Persist the version catalog — every retained version's packed
        tables plus the current/next counters — as one atomically
        renamed npz; returns the catalog content hash.

        The warm-restart counterpart of the request journal:
        ``Engine.recover`` re-pins each replayed request against the
        version it was *admitted* under, which only exists after a
        restart if the catalog was snapshotted. Per-table sha16 hashes
        ride in the metadata and are verified at :meth:`restore` (the
        index checkpoints' sha discipline).
        """
        path = str(path)
        with self._lock:
            versions = dict(self._versions)
            current = self._current.version
            next_version = self._next_version
        payload, shas = {}, {}
        for v, dv in versions.items():
            for name in TABLES:
                key = f"v{v}_{name}"
                a = np.ascontiguousarray(np.asarray(getattr(dv.arrays, name),
                                                    dtype=np.int32))
                payload[key] = a
                shas[key] = hashlib.sha256(a.tobytes()).hexdigest()[:16]
        meta = {"versions": sorted(versions), "current": current,
                "next_version": next_version, "residency": self._residency,
                "infix": self._infix, "dict_block_r": self._dict_block_r,
                "keep_history": self._keep_history, "sha": shas}
        meta_json = json.dumps(meta, sort_keys=True)
        payload["__meta__"] = np.frombuffer(meta_json.encode(), np.uint8)
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return hashlib.sha256(meta_json.encode()).hexdigest()[:16]

    @classmethod
    def restore(cls, path, *, injector=None) -> "DictStore":
        """Rebuild a store from :meth:`snapshot`. Every retained version
        is re-resolved at its ORIGINAL version number (the constructor
        path would renumber from 0, orphaning journal pins); per-table
        content hashes are verified first, raising
        :class:`DictSnapshotError` on any mismatch."""
        with np.load(str(path)) as z:
            meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
            tables = {k: np.asarray(z[k]) for k in z.files if k != "__meta__"}
        import jax.numpy as jnp

        self = cls.__new__(cls)
        self._lock = threading.Lock()
        self._pub_lock = threading.Lock()
        self._residency = meta["residency"]
        self._infix = meta["infix"]
        self._dict_block_r = meta["dict_block_r"]
        self._keep_history = meta["keep_history"]
        self._versions = {}
        self._current = None
        self._injector = None
        for v in meta["versions"]:
            arrs = {}
            for name in TABLES:
                key = f"v{v}_{name}"
                a = np.ascontiguousarray(tables[key].astype(np.int32))
                got = hashlib.sha256(a.tobytes()).hexdigest()[:16]
                if got != meta["sha"][key]:
                    raise DictSnapshotError(
                        f"snapshot table {key} fails its content hash"
                        f" (want {meta['sha'][key]}, got {got})")
                arrs[name] = jnp.asarray(a)
            handle = core_stemmer.resolve_dict(
                core_stemmer.RootDictArrays(**arrs),
                residency=self._residency, infix=self._infix,
                dict_block_r=self._dict_block_r)
            self._versions[int(v)] = DictVersion(int(v), handle)
        self._current = self._versions[int(meta["current"])]
        self._next_version = int(meta["next_version"])
        self._injector = injector
        return self
