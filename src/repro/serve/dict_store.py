"""Versioned root-dictionary store for serving-time lexicon hot swaps.

The streamed megakernel layout (DESIGN.md §5.3) keeps dictionary tiles
in HBM, so swapping the lexicon between tile launches costs a device
upload, not an engine restart. This module supplies the serving-side
contract for that swap:

  publish(arrays)  upload a new dictionary as the next monotonically
                   increasing version; it becomes current atomically and
                   is picked up by the *next* tile launch
  acquire()        snapshot the current version; a tick holds its
                   snapshot for the whole tile launch so a concurrent
                   publish never changes a tile mid-flight

Each version wraps its arrays in a ``core.stemmer.ResolvedRootDict``
handle at publish time: residency="auto" is resolved against the VMEM
budget once, so a swap whose arrays keep their shapes replays the
megakernel's cached jit trace (no re-trace on the serving hot path).
Responses record the version(s) that served them (StemRequest.dict_
versions), and ``get(version)`` resolves any published version back to
its arrays, so served roots stay auditable after further swaps.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.core import pyref
from repro.core import stemmer as core_stemmer


@dataclass(frozen=True)
class DictVersion:
    """One published dictionary: immutable (version, resolved handle)."""

    version: int
    handle: core_stemmer.ResolvedRootDict

    @property
    def arrays(self) -> core_stemmer.RootDictArrays:
        return self.handle.arrays

    @property
    def n_keys(self) -> int:
        return self.handle.n_keys


class DictStore:
    """Versioned RootDictArrays with publish/acquire semantics.

    Versions start at 0 (the constructor publishes the initial
    dictionary) and only ever grow. ``keep_history=False`` drops
    superseded versions on publish for long-lived servers that don't
    need ``get()`` on old versions.
    """

    def __init__(self, arrays, *, residency: str = "auto",
                 keep_history: bool = True):
        self._lock = threading.Lock()
        self._residency = residency
        self._keep_history = keep_history
        self._versions: dict[int, DictVersion] = {}
        self._current: DictVersion | None = None
        self._next_version = 0
        self.publish(arrays)

    def publish(self, arrays) -> int:
        """Upload a new lexicon; returns its version number.

        Accepts packed RootDictArrays (or an already-resolved handle) or
        a raw pyref.RootDict, which is packed here. The new version
        becomes current atomically; in-flight ticks keep the snapshot
        they acquired.
        """
        if isinstance(arrays, pyref.RootDict):
            arrays = core_stemmer.RootDictArrays.from_rootdict(arrays)
        handle = core_stemmer.resolve_dict(arrays, residency=self._residency)
        with self._lock:
            version = self._next_version
            self._next_version += 1
            dv = DictVersion(version, handle)
            if not self._keep_history:
                self._versions.clear()
            self._versions[version] = dv
            self._current = dv
        return version

    def acquire(self) -> DictVersion:
        """Snapshot the current version (hold it for a whole tile launch)."""
        with self._lock:
            return self._current

    def get(self, version: int) -> DictVersion:
        """Resolve a previously published version (audit / parity checks)."""
        with self._lock:
            try:
                return self._versions[version]
            except KeyError:
                raise KeyError(
                    f"dict version {version} not in store (published so far:"
                    f" {self._next_version}, keep_history="
                    f"{self._keep_history})") from None

    @property
    def version(self) -> int:
        """Version number of the current dictionary."""
        with self._lock:
            return self._current.version
