"""Versioned root-dictionary store for serving-time lexicon hot swaps.

The streamed megakernel layout (DESIGN.md §5.3) keeps dictionary tiles
in HBM, so swapping the lexicon between tile launches costs a device
upload, not an engine restart. This module supplies the serving-side
contract for that swap:

  publish(arrays)  upload a new dictionary as the next monotonically
                   increasing version; it becomes current atomically and
                   is picked up by the *next* tile launch
  publish_delta()  the same, but as insert/remove key lists sorted-merged
                   against the current version — untouched tables keep
                   their device arrays instead of re-uploading
  acquire()        snapshot the current version; a dispatch holds its
                   snapshot for the whole tile launch (and through
                   retire), so a concurrent publish never changes — or
                   relabels — a tile in flight

Each version wraps its arrays in a ``core.stemmer.ResolvedRootDict``
handle at publish time: residency="auto" is resolved against the VMEM
budget once (scoped by ``infix`` to the tables the sweep loads), so a
swap whose arrays keep their shapes replays the megakernel's cached jit
trace (no re-trace on the serving hot path). Constructing the store
with ``dict_block_r`` additionally pins the streamed layout's
``DictTileSet`` — the padded `[tri | quad | bi]` tile stream plus the
per-tile boundary tables the tile-visit pre-pass needs — into every
published handle, so serving launches never re-pad or re-concatenate
the dictionary per call and hot swaps keep the cached trace.
Responses record the version(s) that served them (StemRequest.dict_
versions), and ``get(version)`` resolves any published version back to
its arrays, so served roots stay auditable after further swaps.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.core import alphabet as ab
from repro.core import pyref
from repro.core import stemmer as core_stemmer

TABLES = ("tri", "quad", "bi")


def _sorted_member(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Membership of sorted ``needles`` in sorted ``haystack`` via one
    searchsorted pass (no re-sort, unlike np.isin/setdiff1d)."""
    if not haystack.size:
        return np.zeros(needles.shape, bool)
    at = np.minimum(np.searchsorted(haystack, needles), haystack.size - 1)
    return haystack[at] == needles


def _delta_keys(spec) -> np.ndarray:
    """Delta key list -> sorted unique packed int32 keys. Raw root
    strings encode through the alphabet (pack_key takes dense *codes*,
    not characters); packed ints pass through."""
    if spec is None:
        return np.zeros(0, np.int32)
    keys = [ab.pack_key(ab.encode_word(k)) if isinstance(k, str) else int(k)
            for k in spec]
    return np.unique(np.asarray(keys, np.int32)) if keys else np.zeros(0, np.int32)


@dataclass(frozen=True)
class DictVersion:
    """One published dictionary: immutable (version, resolved handle)."""

    version: int
    handle: core_stemmer.ResolvedRootDict

    @property
    def arrays(self) -> core_stemmer.RootDictArrays:
        return self.handle.arrays

    @property
    def n_keys(self) -> int:
        return self.handle.n_keys


class DictStore:
    """Versioned RootDictArrays with publish/acquire semantics.

    Versions start at 0 (the constructor publishes the initial
    dictionary) and only ever grow. ``keep_history=False`` drops
    superseded versions on publish for long-lived servers that don't
    need ``get()`` on old versions.
    """

    def __init__(self, arrays, *, residency: str = "auto",
                 keep_history: bool = True, infix: bool = True,
                 dict_block_r: int | None = None):
        self._lock = threading.Lock()       # guards the version table
        self._pub_lock = threading.Lock()   # serialises publishers
        self._residency = residency
        self._infix = infix
        self._dict_block_r = dict_block_r
        self._keep_history = keep_history
        self._versions: dict[int, DictVersion] = {}
        self._current: DictVersion | None = None
        self._next_version = 0
        self.publish(arrays)

    def _install(self, handle: core_stemmer.ResolvedRootDict) -> int:
        with self._lock:
            version = self._next_version
            self._next_version += 1
            dv = DictVersion(version, handle)
            if not self._keep_history:
                self._versions.clear()
            self._versions[version] = dv
            self._current = dv
        return version

    def publish(self, arrays) -> int:
        """Upload a new lexicon; returns its version number.

        Accepts packed RootDictArrays (or an already-resolved handle) or
        a raw pyref.RootDict, which is packed here. The new version
        becomes current atomically; in-flight ticks keep the snapshot
        they acquired.
        """
        with self._pub_lock:
            if isinstance(arrays, pyref.RootDict):
                arrays = core_stemmer.RootDictArrays.from_rootdict(arrays)
            handle = core_stemmer.resolve_dict(
                arrays, residency=self._residency, infix=self._infix,
                dict_block_r=self._dict_block_r)
            return self._install(handle)

    def publish_delta(self, insert=None, remove=None) -> int:
        """Publish the next version as a sorted-merge delta against the
        current one; returns the new version number.

        ``insert`` / ``remove`` map table names ("tri" / "quad" / "bi")
        to key lists — packed int32 keys or raw root strings (encoded
        and packed through the alphabet). Only the touched tables are
        merged on the host and re-uploaded; untouched tables share the
        version's device arrays, so for large lexicons a small delta
        costs O(delta + touched table) instead of a whole-lexicon
        re-upload (the swap-latency rows in
        benchmarks/serve_throughput.py measure the difference).

        Removing a key that is not present raises ValueError (a delta
        that doesn't apply cleanly is a caller bug, not a no-op), as
        does a key appearing in both lists for the same table. Inserting
        an already-present key is idempotent.
        """
        insert = dict(insert or {})
        remove = dict(remove or {})
        unknown = (set(insert) | set(remove)) - set(TABLES)
        if unknown:
            raise ValueError(f"unknown dictionary tables: {sorted(unknown)}"
                             f" (want subset of {TABLES})")
        import jax.numpy as jnp

        with self._pub_lock:
            cur = self.acquire().arrays
            merged = {}
            for name in TABLES:
                ins = _delta_keys(insert.get(name))
                rem = _delta_keys(remove.get(name))
                old = getattr(cur, name)
                if not ins.size and not rem.size:
                    merged[name] = old      # untouched: same device buffer
                    continue
                both = np.intersect1d(ins, rem)
                if both.size:
                    raise ValueError(
                        f"{name}: keys {both.tolist()} appear in both"
                        " insert and remove")
                host = np.asarray(old)
                host = host[host >= 0]      # drop the empty-table sentinel
                # both sides are sorted: one searchsorted pass per list
                # (no re-sort of the table, unlike union1d/setdiff1d)
                if rem.size:
                    found = _sorted_member(host, rem)
                    if not found.all():
                        raise ValueError(
                            f"{name}: cannot remove absent keys"
                            f" {rem[~found].tolist()}")
                    keep = np.ones(host.size, bool)
                    keep[np.searchsorted(host, rem)] = False
                    host = host[keep]
                if ins.size:
                    ins = ins[~_sorted_member(host, ins)]  # idempotent
                    host = np.insert(host, np.searchsorted(host, ins), ins)
                out = host.astype(np.int32)
                if not out.size:
                    out = np.asarray([-1], np.int32)  # empty-table sentinel
                merged[name] = jnp.asarray(out)
            arrays = core_stemmer.RootDictArrays(**merged)
            handle = core_stemmer.resolve_dict(
                arrays, residency=self._residency, infix=self._infix,
                dict_block_r=self._dict_block_r)
            return self._install(handle)

    def acquire(self) -> DictVersion:
        """Snapshot the current version (hold it for a whole tile launch)."""
        with self._lock:
            return self._current

    def get(self, version: int) -> DictVersion:
        """Resolve a previously published version (audit / parity checks)."""
        with self._lock:
            try:
                return self._versions[version]
            except KeyError:
                raise KeyError(
                    f"dict version {version} not in store (published so far:"
                    f" {self._next_version}, keep_history="
                    f"{self._keep_history})") from None

    @property
    def version(self) -> int:
        """Version number of the current dictionary."""
        with self._lock:
            return self._current.version
