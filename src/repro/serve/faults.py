"""Deterministic fault injection for the serving / indexing stack.

Production serving survives faults only if the recovery paths are
exercised constantly, so every failure mode the engine and the index
builder claim to tolerate is drivable from here, deterministically:

  site ``dispatch``   fail (raise) or delay (sleep) the Nth launch the
                      injector sees — the StemmerWorkload ring and the
                      chunked index builder both report each compute
                      launch before running it.
  site ``retire``     corrupt the host copy of a retired tile's device
                      arrays *before* checksum verification, simulating
                      a torn readback / DMA fault.
  site ``publish``    reject the Nth ``DictStore`` publish after
                      validation but before the version bump — proving
                      the two-phase publish leaves the store untouched.
  site ``checkpoint`` tear (truncate) the Nth index-checkpoint file as
                      it is written, before the builder's readback
                      verification.
  site ``stall``      wedge the Nth *persistent* launch: the ring treats
                      it as never-ready until the watchdog abandons it
                      and re-dispatches the unretired descriptors down
                      the megabatch path. ``retired_tiles`` on the spec
                      says how many leading descriptors "completed"
                      before the wedge (their results are salvaged).
  site ``device_loss``raise :class:`DeviceLost` at the Nth *sharded*
                      launch — the deterministic stand-in for losing a
                      device out of the ``("data",)`` mesh; the
                      degradation ladder reshards onto fewer devices.
  site ``journal``    tear the Nth write-ahead journal append in half —
                      the torn tail a crash mid-write leaves, which
                      recovery must truncate.

A :class:`FaultPlan` is a seeded, ordered tuple of :class:`FaultSpec`s
plus an optional poison set: any dispatch whose request ids intersect
``poison_rids`` fails *every* time, which is what drives the engine's
bisection quarantine. Event counting is per site and strictly
sequential, so a given (plan, workload) pair replays the same faults on
every run — the chaos matrix in CI relies on that to assert bit-identical
recovery.

The default is no injector at all (``injector=None`` everywhere), and
callers guard every hook behind ``if injector is not None``; the fault
layer costs the hot path nothing when unused.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

SITES = ("dispatch", "retire", "publish", "checkpoint", "stall",
         "device_loss", "journal")

# legal fault kinds per site (first entry is the default for the site)
KINDS = {
    "dispatch": ("fail", "delay"),
    "retire": ("corrupt",),
    "publish": ("reject",),
    "checkpoint": ("tear",),
    "stall": ("wedge",),
    "device_loss": ("lost",),
    "journal": ("tear",),
}


class InjectedFault(RuntimeError):
    """Raised by the injector at a faulted event (and nowhere else)."""


class DeviceLost(InjectedFault):
    """A sharded launch lost a device of its mesh (site ``device_loss``)."""


@dataclass(frozen=True)
class FailureInfo:
    """Structured terminal failure attached to a request.

    ``code`` is one of:
      ``quarantined``  the request was isolated by retry bisection (its
                       launches kept failing after ``max_retries``)
      ``deadline``     the request's deadline expired before it finished
      ``shed``         admission control rejected it at a full queue
      ``cancelled``    ``run_until_drained(on_undrained="raise")`` or
                       ``cancel_pending()`` tore it down mid-flight
    ``retries`` counts the dispatch attempts charged to the request's
    last failing group; ``detail`` carries the underlying exception text.
    """

    rid: int
    code: str
    retries: int = 0
    detail: str = ""


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fire at the ``at``-th event (0-based) a
    site sees, for ``count`` consecutive events."""

    site: str
    kind: str = ""            # "" -> the site's default kind
    at: int = 0
    count: int = 1
    delay_s: float = 0.02     # kind="delay" only
    retired_tiles: int = 0    # kind="wedge" only: descriptors done pre-wedge

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}"
                             f" (choose from {SITES})")
        kind = self.kind or KINDS[self.site][0]
        object.__setattr__(self, "kind", kind)
        if kind not in KINDS[self.site]:
            raise ValueError(f"site {self.site!r} supports kinds"
                             f" {KINDS[self.site]}, not {kind!r}")
        if self.at < 0 or self.count < 1:
            raise ValueError("need at >= 0 and count >= 1")
        if self.retired_tiles < 0:
            raise ValueError(
                f"retired_tiles must be >= 0, got {self.retired_tiles}")

    def covers(self, event: int) -> bool:
        return self.at <= event < self.at + self.count


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable set of faults.

    ``poison_rids`` marks requests as poison pills: any dispatch whose
    segment set includes one of them fails unconditionally (on top of
    whatever the occurrence-counted specs do), independent of event
    order — the deterministic stand-in for "this input crashes the
    kernel every time".
    """

    specs: tuple = ()
    seed: int = 0
    poison_rids: frozenset = field(default_factory=frozenset)

    def __post_init__(self):
        specs = tuple(self.specs)
        for s in specs:
            # a duck-typed tuple/dict (or a spec whose site dodged
            # FaultSpec validation) would be carried but never fire —
            # a chaos plan that silently tests nothing. Reject it here.
            if not isinstance(s, FaultSpec):
                raise TypeError(
                    f"FaultPlan specs must be FaultSpec instances, got"
                    f" {type(s).__name__}: {s!r}")
            if s.site not in SITES:
                raise ValueError(f"unknown fault site {s.site!r}"
                                 f" (choose from {SITES})")
        object.__setattr__(self, "specs", specs)
        object.__setattr__(self, "poison_rids",
                           frozenset(int(r) for r in self.poison_rids))


class FaultInjector:
    """Executes a :class:`FaultPlan`; one instance per run.

    Carries per-site event counters and a ``fired`` log of
    ``(site, kind, event_index)`` tuples so tests and the chaos matrix
    can assert the plan actually triggered. Corruption draws from a rng
    seeded by ``(plan.seed, event_index)`` — deterministic per event, so
    replays corrupt identically.
    """

    def __init__(self, plan: FaultPlan | None = None):
        self.plan = plan or FaultPlan()
        self.events = {site: 0 for site in SITES}
        self.fired: list[tuple] = []

    # -- bookkeeping --------------------------------------------------
    def _step(self, site: str) -> list[FaultSpec]:
        ev = self.events[site]
        self.events[site] = ev + 1
        hits = [s for s in self.plan.specs
                if s.site == site and s.covers(ev)]
        for s in hits:
            self.fired.append((site, s.kind, ev))
        return hits

    # -- the four sites ----------------------------------------------
    def on_dispatch(self, rids=()) -> None:
        """Called once per compute launch, before it runs. Raises
        :class:`InjectedFault` to fail the launch, or sleeps to delay
        it; poison rids fail unconditionally."""
        ev = self.events["dispatch"]
        hits = self._step("dispatch")
        poisoned = self.plan.poison_rids.intersection(int(r) for r in rids)
        if poisoned:
            self.fired.append(("dispatch", "poison", ev))
            raise InjectedFault(
                f"injected poison dispatch (rids {sorted(poisoned)})")
        for s in hits:
            if s.kind == "delay":
                import time
                time.sleep(s.delay_s)
            else:
                raise InjectedFault(f"injected dispatch failure (event {ev})")

    def on_retire(self, roots: np.ndarray, sources: np.ndarray):
        """Called with the host copies of a retired tile's arrays,
        before checksum verification. Returns (possibly corrupted)
        arrays; corruption is a deterministic bit flip."""
        ev = self.events["retire"]
        hits = self._step("retire")
        if not hits:
            return roots, sources
        rng = np.random.default_rng((self.plan.seed, ev))
        roots = np.array(roots, copy=True)
        row = int(rng.integers(0, roots.shape[0]))
        roots[row, int(rng.integers(0, roots.shape[1]))] ^= 0x5A
        return roots, sources

    def on_publish(self) -> None:
        """Called between validation and the atomic version bump."""
        ev = self.events["publish"]
        if self._step("publish"):
            raise InjectedFault(f"injected publish rejection (event {ev})")

    def on_checkpoint(self, path: str) -> None:
        """Called on a freshly written (not yet renamed) checkpoint
        file; tearing truncates it mid-record."""
        if not self._step("checkpoint"):
            return
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))

    def on_stall(self) -> FaultSpec | None:
        """Called once per *persistent* launch, after it dispatches.
        Returns the covering wedge spec (the serving ring then treats
        the launch as never-ready until the watchdog abandons it;
        ``spec.retired_tiles`` leading descriptors count as completed
        before the wedge) or None."""
        hits = self._step("stall")
        return hits[0] if hits else None

    def on_device_loss(self) -> None:
        """Called once per *sharded* launch, before it runs. Raises
        :class:`DeviceLost` at a faulted event — the degradation
        ladder's cue to reshard onto fewer data devices."""
        ev = self.events["device_loss"]
        if self._step("device_loss"):
            raise DeviceLost(f"injected device loss (event {ev})")

    def on_journal(self, path: str, nbytes: int = 0) -> None:
        """Called after each journal append with the appended record's
        byte length; tearing truncates that record in half — the torn
        tail a crash mid-write leaves for recovery to drop."""
        if not self._step("journal"):
            return
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(0, size - max(1, nbytes // 2)))
