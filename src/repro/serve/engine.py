"""Workload-agnostic serving core: queue/admit/finish continuous batching.

The scheduler (:class:`Engine`) owns what is generic about continuous
batching — the FIFO request queue, rid allocation, admission while the
workload has capacity, the finished table, and drain accounting. What a
"tick" of work means is delegated to a :class:`Workload`:

  LMDecodeWorkload   the LM decode path: a fixed pool of B slots,
                     prefill-by-decode splicing the prompt's KV into the
                     slot's region of the batched cache, one decoded
                     token per live slot per tick, finished slots free
                     immediately. Bit-identical to the pre-refactor
                     ServeEngine (which remains as a facade).
  StemmerWorkload    the paper's workload behind the same machinery:
                     queued word-batch requests coalesce into megabatches
                     of up to ``megabatch_tiles`` [data_devices *
                     block_b, 16] super-tiles, each megabatch ONE
                     megakernel launch whose grid spans every coalesced
                     tile (ops.extract_roots_fused,
                     ops.extract_roots_persistent for the
                     descriptor-ring kernel, or ops.extract_roots_sharded
                     across a data mesh). A
                     tick is a dispatch/retire pipeline pass: up to
                     max_inflight launches stay outstanding as device
                     arrays while the host coalesces the next tiles;
                     results scatter back at retire, when they are
                     ready. The dictionary is acquired from a
                     serve.dict_store.DictStore at each *dispatch* and
                     pinned per launch, so lexicon hot swaps land
                     between launches — never inside one — and every
                     served word records the dict version that actually
                     served it, even when the publish lands while its
                     tile is in flight.

Keeping the tile shape fixed means every launch replays the same jit
trace; dictionary swaps with matching shapes also replay it (the
DictStore pins residency in a ResolvedRootDict handle at publish time).

Failure model (DESIGN.md "Failure model & recovery"): requests carry
optional deadlines, the queue has optional cap-based admission control
(``on_full="raise"|"shed"|"block"``), and the stemmer's dispatch/retire
ring retries failed / timed-out / corrupted launches up to
``max_retries`` before bisecting the tile to quarantine the poison
request(s) — every terminal failure is returned through the finished
table with a structured :class:`~repro.serve.faults.FailureInfo`
instead of wedging the batch. Retire verifies a device-computed
per-tile checksum on every path (the persistent kernel's completion
flags generalised), and ``run_until_drained(on_undrained="raise")``
cancels stranded requests so the engine stays reusable after the
exception.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.models import model as model_mod
from repro.serve.faults import DeviceLost, FailureInfo
from repro.serve.health import EventLog


# ---------------------------------------------------------------------------
# the workload contract
# ---------------------------------------------------------------------------
@runtime_checkable
class Workload(Protocol):
    """What the generic Engine needs from a servable workload."""

    def make_request(self, rid: int, payload, **opts):
        """Validate + wrap a submission; raise ValueError on bad configs."""

    def has_capacity(self) -> bool:
        """Can admit() take one more request right now?"""

    def admit(self, request) -> None:
        """Move a queued request in-flight (e.g. prefill into a slot)."""

    def tick(self) -> list:
        """Advance all in-flight work one step; return finished requests."""

    @property
    def active(self) -> int:
        """Number of in-flight (admitted, unfinished) requests."""

    def pending_rids(self) -> list[int]:
        """rids of in-flight requests (for drain reports)."""

    def expire(self, now: float) -> list:
        """Fail + return in-flight requests whose deadline passed."""

    def cancel_pending(self) -> list:
        """Tear down all in-flight work; fail + return the requests."""


# ---------------------------------------------------------------------------
# drain accounting
# ---------------------------------------------------------------------------
@dataclass
class DrainReport:
    """Outcome of run_until_drained: ticks spent and what is still owed."""

    ticks: int
    drained: bool
    pending: list[int]   # rids still queued or in flight at max_ticks
    cancelled: list = field(default_factory=list)
    # rids cancelled+returned through finished (on_undrained="raise"
    # tears stranded work down so the engine is reusable; each cancelled
    # request carries a FailureInfo(code="cancelled"))


class EngineUndrained(RuntimeError):
    """max_ticks elapsed with requests still queued or in flight."""

    def __init__(self, report: DrainReport):
        self.report = report
        super().__init__(
            f"engine not drained after {report.ticks} ticks:"
            f" {len(report.pending)} request(s) unfinished"
            f" (rids {report.pending};"
            f" {len(report.cancelled)} cancelled + returned)")


class QueueFull(RuntimeError):
    """submit() against a full queue under on_full="raise"."""


# ---------------------------------------------------------------------------
# the generic scheduler
# ---------------------------------------------------------------------------
class Engine:
    """Continuous batching over any Workload.

    submit() validates through the workload and queues; step() admits
    while the workload has capacity, then runs one workload tick;
    finished requests move to the results table keyed by rid.

    ``queue_cap`` bounds the *queued* (not yet admitted) requests;
    submits against a full queue follow ``on_full``: "raise" rejects
    with :class:`QueueFull`, "shed" finishes the request immediately
    with ``FailureInfo(code="shed")`` (the overload-protection path —
    the caller still gets a rid and a structured result), "block"
    serves the backlog inline until a slot opens. ``deadline_s`` on
    submit stamps an absolute deadline; expiry (checked each step,
    whether the request is queued or in flight) finishes it with
    ``FailureInfo(code="deadline")`` while later requests proceed.
    """

    ON_FULL = ("raise", "shed", "block")

    def __init__(self, workload: Workload, *, queue_cap: int | None = None,
                 on_full: str = "raise", journal=None, policy=None):
        if on_full not in self.ON_FULL:
            raise ValueError(f"unknown on_full policy {on_full!r}"
                             f" (choose from {self.ON_FULL})")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {queue_cap}")
        if on_full != "raise" and queue_cap is None:
            raise ValueError(f"on_full={on_full!r} needs a queue_cap"
                             " (an unbounded queue is never full)")
        self.workload = workload
        self.queue: list = []
        self.finished: dict[int, object] = {}
        self.queue_cap = queue_cap
        self.on_full = on_full
        self.shed = 0            # requests rejected by admission control
        self._next_rid = 0
        # crash safety + health (DESIGN.md §12): the write-ahead journal
        # makes accepted work durable; the event log is the one stream
        # failures / stalls / ladder transitions surface through; the
        # degradation policy (observed each step) walks the mode ladder
        self.journal = journal
        self.events_log: EventLog = (getattr(workload, "events", None)
                                     or EventLog())
        self.policy = policy
        if policy is not None:
            policy.attach(workload, self.events_log)
        self.recovery = None     # RecoveryReport when built via recover()

    # -- client API --------------------------------------------------------
    def _queue_full(self) -> bool:
        return (self.queue_cap is not None
                and len(self.queue) >= self.queue_cap)

    def submit(self, payload, *, deadline_s: float | None = None,
               **opts) -> int:
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        if self._queue_full():
            if self.on_full == "raise":
                raise QueueFull(
                    f"queue at cap {self.queue_cap}; submit rejected"
                    " (on_full='raise')")
            if self.on_full == "block":
                for _ in range(100_000):
                    self.step()
                    if not self._queue_full():
                        break
                else:
                    raise RuntimeError(
                        "on_full='block' made no progress against a full"
                        " queue — the workload is wedged")
        req = self.workload.make_request(self._next_rid, payload, **opts)
        rid = self._next_rid
        self._next_rid += 1
        if deadline_s is not None:
            req.deadline = time.monotonic() + deadline_s
        if self._queue_full():           # only reachable under "shed"
            req.failure = FailureInfo(rid, "shed",
                                      detail=f"queue at cap {self.queue_cap}")
            req.done = True
            self._finish(req)           # shed work is terminal, never
            self.shed += 1              # journaled as an admit
            return rid
        if self.journal is not None:
            # write-ahead: the admit is durable BEFORE the request can
            # be served, so a crash between here and retire re-serves it
            store = getattr(self.workload, "store", None)
            self.journal.admit(
                rid, payload, deadline_s=deadline_s,
                dict_version=None if store is None else store.version,
                opts=opts)
        self.queue.append(req)
        return rid

    def result(self, rid: int):
        return self.finished.get(rid)

    def events(self, *, drain: bool = False) -> list:
        """The structured event stream (failures, retries, checksum and
        flag mismatches, watchdog stalls, device losses, ladder
        transitions, recovery) — the supported alternative to grepping
        workload counters."""
        return (self.events_log.drain() if drain
                else self.events_log.snapshot())

    def _finish(self, req) -> None:
        """Single exit into the finished table: emits the failure event
        and the journal retire record alongside."""
        self.finished[req.rid] = req
        if req.failure is not None:
            self.events_log.emit("failure", rid=req.rid,
                                 code=req.failure.code,
                                 detail=req.failure.detail)
        if self.journal is not None:
            self.journal.retire(req)

    @property
    def active(self) -> int:
        return self.workload.active

    # -- scheduling --------------------------------------------------------
    def step(self):
        """One engine tick: expire deadlines, admit while there is
        capacity, then tick the workload."""
        now = time.monotonic()
        if self.queue:
            still = []
            for req in self.queue:
                dl = getattr(req, "deadline", None)
                if dl is not None and now > dl:
                    req.failure = FailureInfo(req.rid, "deadline",
                                              detail="expired while queued")
                    req.done = True
                    self._finish(req)
                else:
                    still.append(req)
            self.queue = still
        expire = getattr(self.workload, "expire", None)
        if expire is not None:
            for req in expire(now):
                self._finish(req)
        while self.queue and self.workload.has_capacity():
            self.workload.admit(self.queue.pop(0))
        for req in self.workload.tick():
            self._finish(req)
        if self.policy is not None:
            self.policy.observe(self)

    def run_until_drained(self, max_ticks: int = 1000, *,
                          on_undrained: str = "raise") -> DrainReport:
        """Tick until queue + in-flight are empty, or max_ticks elapse.

        Hitting max_ticks with work outstanding never silently drops it:
        on_undrained="raise" (default) cancels the stranded requests —
        each lands in the finished table with FailureInfo(code=
        "cancelled") — and raises EngineUndrained carrying the report
        (pending + cancelled rids), leaving the engine empty and
        reusable for new work; "return" hands back the report with
        drained=False and the unfinished rids, leaving the queue and
        in-flight work intact so the same drain can be resumed.
        """
        if on_undrained not in ("raise", "return"):
            raise ValueError(f"unknown on_undrained policy: {on_undrained!r}")
        ticks = 0
        while (self.queue or self.workload.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        pending = ([r.rid for r in self.queue]
                   + self.workload.pending_rids())
        if pending and on_undrained == "raise":
            cancelled = []
            for req in self.queue:
                req.failure = FailureInfo(req.rid, "cancelled",
                                          detail="undrained at max_ticks"
                                                 " (still queued)")
                req.done = True
                self._finish(req)
                cancelled.append(req.rid)
            self.queue = []
            cancel = getattr(self.workload, "cancel_pending", None)
            if cancel is not None:
                for req in cancel():
                    self._finish(req)
                    cancelled.append(req.rid)
            raise EngineUndrained(DrainReport(ticks=ticks, drained=False,
                                              pending=pending,
                                              cancelled=cancelled))
        return DrainReport(ticks=ticks, drained=not pending,
                           pending=pending)

    # -- warm restart ------------------------------------------------------
    @classmethod
    def recover(cls, journal_path, workload: Workload, *,
                queue_cap: int | None = None, on_full: str = "raise",
                policy=None, fsync_every: int = 32) -> "Engine":
        """Rebuild an engine from a write-ahead journal after a crash.

        Reads the journal (truncating any torn tail), re-queues every
        admit with no matching retire — in rid order, through the normal
        FIFO path, so replay coalesces and serves deterministically —
        and reopens the journal for appending. Replayed requests
        re-verify their payload digest, re-arm their original deadline
        window, and re-pin the dict version they were admitted under
        (``workload.store`` must still hold it: pair the journal with
        ``DictStore.snapshot``/``restore``). Requests already retired
        are NOT re-served; their responses live in the journal's retire
        digests. The combined (pre-crash finished + recovered) outputs
        are bit-identical to an uninterrupted run.
        """
        from repro.serve import journal as journal_mod

        records, dropped = journal_mod.Journal.read(journal_path)
        injector = getattr(workload, "injector", None)
        jr = journal_mod.Journal(journal_path, fsync_every=fsync_every,
                                 injector=injector)
        eng = cls(workload, queue_cap=queue_cap, on_full=on_full,
                  journal=jr, policy=policy)
        retired = {int(r["rid"]) for r in records
                   if r.get("kind") == "retire"}
        max_rid, replayed = -1, []
        for rec in records:
            if rec.get("kind") == "retire":
                max_rid = max(max_rid, int(rec["rid"]))
                continue
            rid = int(rec["rid"])
            max_rid = max(max_rid, rid)
            if rid in retired:
                continue
            payload = journal_mod.decode_payload(rec["payload"])
            if journal_mod.payload_digest(payload) != rec["digest"]:
                raise journal_mod.JournalError(
                    f"admit record for rid {rid} fails its payload digest")
            req = workload.make_request(rid, payload,
                                        **(rec.get("opts") or {}))
            if rec.get("deadline_s") is not None:
                req.deadline = time.monotonic() + float(rec["deadline_s"])
            dv = rec.get("dict_version")
            if dv is not None and hasattr(req, "pin_version"):
                req.pin_version = int(dv)
            eng.queue.append(req)
            replayed.append(rid)
        eng._next_rid = max_rid + 1
        eng.recovery = journal_mod.RecoveryReport(
            replayed=replayed, already_retired=len(retired),
            dropped_bytes=dropped)
        eng.events_log.emit("recovered", replayed=len(replayed),
                            already_retired=len(retired),
                            dropped_bytes=dropped)
        return eng


# ---------------------------------------------------------------------------
# LM decode workload (the pre-refactor ServeEngine body)
# ---------------------------------------------------------------------------
@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32 [T] (or [T,K] audio)
    max_new: int = 16
    tokens_out: list = field(default_factory=list)
    done: bool = False
    deadline: float | None = None       # absolute time.monotonic() bound
    failure: FailureInfo | None = None  # set iff terminally failed


class LMDecodeWorkload:
    """Slot-per-request greedy decode over the jitted decode step.

    Requests enter a fixed pool of B slots; prefill computes the
    prompt's KV (state) which is spliced into the slot's region of the
    batched cache; every tick decodes one token for all live slots;
    finished slots free immediately for the next queued request.
    """

    def __init__(self, cfg, params, *, max_batch: int = 4,
                 cache_len: int = 128, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.cache_len = cache_len
        self.greedy = greedy
        self.caches = model_mod.init_caches(cfg, max_batch, cache_len)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)   # next position

        self._decode = jax.jit(
            lambda p, tok, caches, pos: model_mod.decode_step(
                p, cfg, tok, caches, pos))

    # -- workload protocol -------------------------------------------------
    def make_request(self, rid: int, prompt, *, max_new: int = 16) -> Request:
        if max_new < 1:
            # prefill always emits the first generated token, so the engine
            # cannot return fewer than one token per request
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        return Request(rid, np.asarray(prompt, np.int32), max_new)

    def has_capacity(self) -> bool:
        return any(r is None for r in self.slot_req)

    def admit(self, req: Request):
        self._prefill_into_slot(self.slot_req.index(None), req)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def pending_rids(self) -> list[int]:
        return [r.rid for r in self.slot_req if r is not None]

    def tick(self) -> list[Request]:
        """Decode one token for every live slot.

        Doneness is checked BEFORE decoding: a request admitted this tick
        already holds its prefill-emitted token, so with max_new=1 it must
        free its slot without an extra decode (it would otherwise return
        max_new + 1 tokens).
        """
        finished = []
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None:
                continue
            if len(req.tokens_out) >= req.max_new:
                finished.append(self._finish_slot(slot, req))
                continue
            self._step_slot(slot, req.tokens_out[-1], emit=True)
            if len(req.tokens_out) >= req.max_new:
                finished.append(self._finish_slot(slot, req))
        return finished

    def expire(self, now: float) -> list[Request]:
        """Free + fail slots whose request deadline passed; partial
        tokens stay on the request for the caller to inspect."""
        out = []
        for slot in range(self.B):
            req = self.slot_req[slot]
            if (req is not None and req.deadline is not None
                    and now > req.deadline):
                req.failure = FailureInfo(
                    req.rid, "deadline",
                    detail=f"{len(req.tokens_out)}/{req.max_new} tokens"
                           " decoded")
                out.append(self._finish_slot(slot, req))
        return out

    def cancel_pending(self) -> list[Request]:
        out = []
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is not None:
                req.failure = FailureInfo(
                    req.rid, "cancelled",
                    detail="slot torn down with the request decoding")
                out.append(self._finish_slot(slot, req))
        return out

    # -- decode machinery --------------------------------------------------
    def _prefill_into_slot(self, slot: int, req: Request):
        """Prompt tokens run through decode steps into this slot's cache.

        (Single-slot prefill-by-decode keeps the engine simple and exactly
        consistent with the decode path; bulk prefill would jit
        forward(mode='prefill') and splice — see launch/serve.py.)
        """
        self.slot_req[slot] = req
        self.slot_pos[slot] = 0
        for t, tok in enumerate(req.prompt[:-1]):
            self._step_slot(slot, int(tok), emit=False)
        # last prompt token emits the first generated token
        self._step_slot(slot, int(req.prompt[-1]), emit=True)

    def _step_slot(self, slot: int, token: int, emit: bool):
        cfg = self.cfg
        tok_shape = (self.B, 1, cfg.n_codebooks) if cfg.n_codebooks else (self.B, 1)
        toks = np.zeros(tok_shape, np.int32)
        toks[slot] = token
        pos = jnp.int32(int(self.slot_pos[slot]))
        logits, new_caches = self._decode(self.params, jnp.asarray(toks),
                                          self.caches, pos)
        # merge only this slot's cache rows (positions differ per slot)
        self.caches = _merge_slot(self.caches, new_caches, slot, batch=self.B)
        self.slot_pos[slot] += 1
        if emit:
            req = self.slot_req[slot]
            nxt = int(np.asarray(jnp.argmax(logits[slot, -1], axis=-1)).reshape(-1)[0])
            req.tokens_out.append(nxt)

    def _finish_slot(self, slot: int, req: Request) -> Request:
        req.done = True
        self.slot_req[slot] = None
        return req


# ---------------------------------------------------------------------------
# stemmer workload: word-batch requests through the megakernel
# ---------------------------------------------------------------------------
@dataclass
class StemRequest:
    """A word-batch request and its (incrementally filled) response.

    dict_versions[i] is the DictStore version whose tile launch served
    word i — across a mid-stream publish() a single request may span two
    versions, and the per-word record keeps served roots auditable
    against exactly the lexicon that produced them. ``dispatched`` runs
    ahead of ``served`` while tiles are in flight: a word counts as
    dispatched when its super-tile launches and as served only when the
    launch retires (its results scattered back to this request).
    """

    rid: int
    words: np.ndarray          # int32 [n, 16] encoded words
    roots: np.ndarray          # int32 [n, 4] zero-padded char codes
    sources: np.ndarray        # int32 [n] pyref.SRC_* tags
    dict_versions: np.ndarray  # int32 [n] DictStore version per word
    dispatched: int = 0        # words claimed by a launch (or retry group)
    served: int = 0            # words completed (results scattered back)
    done: bool = False
    deadline: float | None = None       # absolute time.monotonic() bound
    failure: FailureInfo | None = None  # set iff terminally failed
    pin_version: int | None = None      # recovery: serve under exactly this
    # dict version (the one the request was admitted under, per its
    # journal record) instead of whatever is current at dispatch

    @property
    def n_words(self) -> int:
        return int(self.words.shape[0])

    @property
    def dict_version(self) -> int | None:
        """Version that served the request (the last word's, if a hot
        swap landed mid-request; None for empty requests)."""
        return int(self.dict_versions[-1]) if self.dict_versions.size else None


@dataclass
class InflightTile:
    """One dispatched super-tile awaiting retire.

    The results stay device arrays until retire; ``version`` pins the
    DictStore version acquired at *dispatch* time, so a publish() landing
    while this tile is in flight never relabels (or re-serves) its words.
    """

    segments: list             # [(req, req_start, tile_start, count)]
    version: int               # DictStore version pinned at dispatch
    roots_dev: object          # device int32 [launch_b, 4]
    sources_dev: object        # device int32 [launch_b]
    slot: int                  # staging-buffer ring slot held until retire
    flags_dev: object = None   # persistent mode: int32 [n_tiles] completion
    checksums_dev: object = None  # int32 [n_tiles] device-computed per-tile
    retries: int = 0           # retry generation of this dispatch
    t_dispatch: float = 0.0    # launch_timeout_s / watchdog_s accounting
    stalled: object = None     # injected wedge spec: never reads as ready
    via_megabatch: bool = False  # watchdog fallback: bypassed persistent

    def is_ready(self) -> bool:
        """True once the device arrays can be fetched without blocking.

        checksums_dev is never polled: it is an output of the SAME XLA
        program as roots/sources (with_checksum= fuses the fold into the
        launch), so it is ready exactly when they are — and the retire
        tick busy-polls this, so every extra is_ready() call here is paid
        hundreds of times per drain."""
        try:
            return bool(self.roots_dev.is_ready()
                        and self.sources_dev.is_ready()
                        and (self.flags_dev is None
                             or self.flags_dev.is_ready()))
        except AttributeError:   # backend without readiness introspection
            return True


@dataclass
class RetryGroup:
    """A claimed segment set awaiting (re-)dispatch.

    Segments are ``(req, req_start, count)`` — tile offsets are assigned
    at dispatch time, since a retried group repacks from the front of a
    fresh staging slot. ``retries`` counts failed dispatch attempts;
    ``not_before`` implements the retry backoff.
    """

    segments: list             # [(req, req_start, count)]
    retries: int = 0
    not_before: float = 0.0
    via_megabatch: bool = False  # force the megabatch path even when the
    # workload is persistent — the watchdog's descriptor re-dispatch
    # route (a wedged descriptor ring must not be relaunched into)


class StemmerWorkload:
    """Continuous batching of word-batch requests into megakernel tiles,
    dispatch/retire-pipelined so host coalescing overlaps device compute.

    A tick is one scheduling pass over a ring of in-flight launches:

      retire    scatter back every launch whose device arrays are ready
                (non-blocking readiness check; results land in the
                per-request arrays, words move from dispatched to served)
      dispatch  coalesce pending words FIFO into a megabatch of up to
                ``megabatch_tiles`` [data_devices * block_b, 16]
                super-tiles and launch the whole megabatch as ONE
                megakernel call (the grid's batch axis spans every
                coalesced tile) — repeatedly, until ``max_inflight``
                launches are outstanding or no undispatched words remain
      drain     only a tick that would otherwise make NO progress
                blocks: saturated (every slot outstanding, none ready)
                waits for the oldest launch; draining (nothing left to
                dispatch either) hard-syncs the whole ring. A tick that
                retired or launched something never blocks, so a
                trickle-fed server keeps its launches in flight across
                submit/step iterations

    With ``max_inflight=1`` the pipeline degenerates to the synchronous
    dispatch-then-retire tick (overlap off); with ``megabatch_tiles=1``
    (default) each launch is one super-tile, the pre-megabatch contract.
    A partially filled megabatch launches at the next power-of-two
    super-tile count (capped at ``megabatch_tiles``), so a trickle-fed
    queue replays a small bounded set of jit traces instead of one per
    fill level. Tile inputs are built in a preallocated host staging
    buffer per ring slot (no per-tick allocation); each launch pins the
    DictStore version it acquired at dispatch, so hot swaps landing
    between dispatch and retire stay exact per word. ``data_devices > 1``
    routes launches through ``ops.extract_roots_sharded``
    (dist.shard_batch), splitting each megabatch across a ("data",)
    mesh. ``persistent=True`` routes launches through
    ``ops.extract_roots_persistent`` — the single-launch descriptor-ring
    kernel — and retire additionally checks the per-tile completion
    flags against the pinned dict version (the device-side proof that
    every descriptor retired under the version acquired at dispatch).

    Fault tolerance: ``checksum=True`` (default) computes a per-tile
    int32 checksum over (roots, sources) on device at dispatch and
    re-derives it on the host copies at retire — a mismatch (torn
    readback, injected corruption) discards the launch and re-dispatches
    its words. A launch that raises, times out (``launch_timeout_s``),
    or fails the checksum is retried up to ``max_retries`` times (with
    exponential ``retry_backoff_s`` between attempts); a group that
    keeps failing is *bisected* — its segment list split in half, each
    half retried independently — until single-request groups that still
    fail are quarantined with ``FailureInfo(code="quarantined")`` while
    the rest of the batch completes. ``max_retries=0`` restores the
    strict pre-fault-tolerance contract: the first failure unwinds the
    claims and propagates. ``injector`` accepts a
    :class:`~repro.serve.faults.FaultInjector` (None = no fault layer on
    the hot path).
    """

    def __init__(self, store, *, block_b: int = 256, infix: bool = True,
                 match: str = "bsearch", dict_block_r: int = 8,
                 num_buffers: int = 2, skip_index: bool = True,
                 max_inflight: int = 2, data_devices: int = 1,
                 megabatch_tiles: int = 1, persistent: bool = False,
                 max_requests: int | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.0,
                 launch_timeout_s: float | None = None,
                 watchdog_s: float | None = None,
                 checksum: bool = True, injector=None,
                 interpret: bool | None = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if data_devices < 1:
            raise ValueError(f"data_devices must be >= 1, got {data_devices}")
        if megabatch_tiles < 1:
            raise ValueError(
                f"megabatch_tiles must be >= 1, got {megabatch_tiles}")
        if persistent and data_devices > 1:
            raise ValueError(
                "persistent=True is single-device (the descriptor ring is"
                " one kernel's SMEM); use megabatch_tiles for multi-device"
                " coalescing")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {retry_backoff_s}")
        if launch_timeout_s is not None and launch_timeout_s <= 0:
            raise ValueError(
                f"launch_timeout_s must be > 0, got {launch_timeout_s}")
        if watchdog_s is not None and watchdog_s <= 0:
            raise ValueError(f"watchdog_s must be > 0, got {watchdog_s}")
        if watchdog_s is not None and not persistent:
            raise ValueError(
                "watchdog_s guards the persistent descriptor ring"
                " (completion-flag stalls); non-persistent launches use"
                " launch_timeout_s")
        self.store = store
        self.block_b = block_b
        self.infix = infix
        self.match = match
        self.dict_block_r = dict_block_r
        self.num_buffers = num_buffers
        self.skip_index = skip_index
        self.max_inflight = max_inflight
        self.data_devices = data_devices
        self.megabatch_tiles = megabatch_tiles
        self.persistent = persistent
        self.max_requests = max_requests
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.launch_timeout_s = launch_timeout_s
        self.watchdog_s = watchdog_s
        self.checksum = checksum
        self.injector = injector
        self.interpret = interpret
        self.super_b = block_b * data_devices
        self.launch_b = self.super_b * megabatch_tiles
        self.inflight: list[StemRequest] = []
        self.ring: list[InflightTile] = []
        self._requeue: list[RetryGroup] = []
        self.ticks_launched = 0   # megakernel launches (not engine ticks)
        # fault-path accounting (tests + benchmarks/recovery.py read these)
        self.retries_total = 0    # failed dispatch attempts charged
        self.bisections = 0       # groups split after exhausting retries
        self.quarantined = 0      # requests isolated with FailureInfo
        self.timeouts = 0         # launches abandoned at launch_timeout_s
        self.checksum_failures = 0  # retires discarded on checksum mismatch
        self.watchdog_stalls = 0  # persistent launches abandoned as wedged
        self.device_losses = 0    # sharded launches failed with DeviceLost
        # structured incident stream; the Engine adopts this log so
        # workload- and engine-level events interleave in one place
        self.events = EventLog()
        # degradation-ladder state: a requested ServingMode lands at the
        # next tick whose ring is empty; "streamed" overrides resident
        # published handles (degraded re-resolutions cached per version)
        self.residency_override: str | None = None
        self._pending_mode = None
        self._degraded: dict = {}
        self._mesh = None
        if data_devices > 1:
            from repro.launch import mesh as mesh_mod

            self._mesh = mesh_mod.make_data_mesh(data_devices)
        # one reusable host staging buffer per ring slot: dispatch fills
        # segments + zeroes the tail instead of allocating per tick
        self._staging = [np.zeros((self.launch_b, ab.MAXLEN), np.int32)
                         for _ in range(max_inflight)]
        self._free_slots = list(range(max_inflight))

    # -- workload protocol -------------------------------------------------
    def make_request(self, rid: int, words, **opts) -> StemRequest:
        if opts:
            raise ValueError(f"unknown stemmer request options: {sorted(opts)}")
        if isinstance(words, np.ndarray):
            if words.ndim != 2 or words.shape[1] != ab.MAXLEN:
                raise ValueError(
                    f"encoded word batch must be [n, {ab.MAXLEN}], got"
                    f" {words.shape}")
            enc = words.astype(np.int32, copy=True)
        else:
            enc = ab.encode_batch(list(words))  # raw strings
        n = enc.shape[0]
        return StemRequest(rid, enc,
                           roots=np.zeros((n, 4), np.int32),
                           sources=np.zeros(n, np.int32),
                           dict_versions=np.zeros(n, np.int32))

    def has_capacity(self) -> bool:
        return (self.max_requests is None
                or len(self.inflight) < self.max_requests)

    def admit(self, req: StemRequest):
        self.inflight.append(req)

    @property
    def active(self) -> int:
        return len(self.inflight)

    def pending_rids(self) -> list[int]:
        return [r.rid for r in self.inflight]

    def tick(self) -> list[StemRequest]:
        self._apply_pending_mode()
        retired = self._retire_ready()
        dispatched = self._fill_ring()
        if not retired and not dispatched and self.ring:
            # a would-be-zero-progress tick must still make progress.
            # Ticks that retired or launched something never block here,
            # so a trickle-fed server (submit/step one request at a
            # time) keeps its launches in flight and its overlap.
            if self._has_undispatched():
                # saturated: every slot outstanding, none ready — wait
                # for the oldest, then refill its slot
                self._retire_blocking(self.ring.pop(0))
                self._fill_ring()
            else:
                # draining: nothing left to launch, so overlap buys
                # nothing — hard-sync the whole ring
                while self.ring:
                    self._retire_blocking(self.ring.pop(0))
        finished, still = [], []
        for req in self.inflight:
            if req.failure is not None:     # quarantined mid-flight
                req.done = True
                finished.append(req)
            elif req.served >= req.n_words:  # includes empty requests
                req.done = True
                finished.append(req)
            else:
                still.append(req)
        self.inflight = still
        return finished

    def expire(self, now: float) -> list[StemRequest]:
        """Fail + hand back in-flight requests past their deadline.

        Words of an expired request still riding a launch are dropped at
        retire (the scatter skips failed requests); partial results up
        to ``served`` stay on the request for the caller to inspect.
        """
        out, still = [], []
        for req in self.inflight:
            if (req.failure is None and req.deadline is not None
                    and now > req.deadline):
                req.failure = FailureInfo(
                    req.rid, "deadline",
                    detail=f"{req.served}/{req.n_words} words served")
                req.done = True
                out.append(req)
            else:
                still.append(req)
        self.inflight = still
        return out

    def cancel_pending(self) -> list[StemRequest]:
        """Tear down the ring + retry queue; fail every in-flight
        request with FailureInfo(code="cancelled") and return them."""
        for entry in self.ring:
            self._free_slots.append(entry.slot)
        self.ring = []
        self._requeue = []
        out = []
        for req in self.inflight:
            if req.failure is None:
                req.failure = FailureInfo(
                    req.rid, "cancelled",
                    detail=f"{req.served}/{req.n_words} words served")
            req.done = True
            out.append(req)
        self.inflight = []
        return out

    # -- degradation ladder (serve/health.py) ------------------------------
    def request_mode(self, mode) -> None:
        """Ask for a ladder transition: applied at the next tick whose
        ring is empty (in-flight launches keep the geometry they
        dispatched with; resharding mid-launch is never attempted)."""
        self._pending_mode = mode

    def _apply_pending_mode(self) -> None:
        m = self._pending_mode
        if m is None or self.ring:
            return
        self._pending_mode = None
        geom_changed = (m.data_devices != self.data_devices
                        or m.megabatch_tiles != self.megabatch_tiles)
        self.persistent = m.persistent
        self.megabatch_tiles = m.megabatch_tiles
        self.residency_override = m.residency
        if m.data_devices != self.data_devices:
            self.data_devices = m.data_devices
            if m.data_devices > 1:
                from repro.launch import mesh as mesh_mod

                self._mesh = mesh_mod.make_data_mesh(m.data_devices)
            else:
                self._mesh = None
        if geom_changed:
            self.super_b = self.block_b * self.data_devices
            self.launch_b = self.super_b * self.megabatch_tiles
            self._staging = [np.zeros((self.launch_b, ab.MAXLEN), np.int32)
                             for _ in range(self.max_inflight)]
            self._free_slots = list(range(self.max_inflight))
            self._split_requeue(self.launch_b)

    def _split_requeue(self, cap: int) -> None:
        """Re-chunk waiting retry groups so none exceeds the (possibly
        shrunken) launch width after a ladder transition."""
        out = []
        for grp in self._requeue:
            cur, fill = [], 0
            for req, r0, take in grp.segments:
                while take > 0:
                    t = min(take, cap - fill)
                    if t == 0:
                        out.append(RetryGroup(cur, retries=grp.retries,
                                              not_before=grp.not_before,
                                              via_megabatch=grp.via_megabatch))
                        cur, fill = [], 0
                        continue
                    cur.append((req, r0, t))
                    fill += t
                    r0 += t
                    take -= t
            if cur:
                out.append(RetryGroup(cur, retries=grp.retries,
                                      not_before=grp.not_before,
                                      via_megabatch=grp.via_megabatch))
        self._requeue = out

    def _degraded_handle(self, dv):
        """This version's arrays re-resolved at the ladder's residency
        override (e.g. resident -> streamed), cached per (version,
        override) so repeated launches reuse one handle/trace."""
        key = (dv.version, self.residency_override)
        h = self._degraded.get(key)
        if h is None:
            from repro.core import stemmer as core_stemmer

            h = core_stemmer.resolve_dict(
                dv.arrays, residency=self.residency_override,
                infix=self.infix, dict_block_r=self.dict_block_r)
            self._degraded[key] = h
        return h

    # -- dispatch side -----------------------------------------------------
    def _has_undispatched(self) -> bool:
        return bool(self._requeue) or any(
            req.n_words > req.dispatched for req in self.inflight
            if req.failure is None)

    def _coalesce(self) -> list[tuple[StemRequest, int, int]]:
        """FIFO-claim one megabatch (up to ``megabatch_tiles``
        super-tiles) of undispatched words: -> [(req, req_start, count)].

        Claiming advances ``req.dispatched`` immediately — a failed
        launch keeps its words through the RetryGroup rather than
        releasing them for re-coalescing (which could double-dispatch
        against an in-flight retry).

        A launch acquires ONE dict version, so requests with different
        ``pin_version``s (recovery pins the admit-time version; fresh
        requests pin nothing) never share a group — coalescing breaks
        at the first pin mismatch and picks the rest up next launch.
        """
        segments, fill, pin = [], 0, None
        for req in self.inflight:
            if req.failure is not None:
                continue
            if fill >= self.launch_b:
                break
            take = min(req.n_words - req.dispatched, self.launch_b - fill)
            if take > 0:
                if not segments:
                    pin = req.pin_version
                elif req.pin_version != pin:
                    break
                segments.append((req, req.dispatched, take))
                req.dispatched += take
                fill += take
        return segments

    def _bucket_rows(self, fill: int) -> int:
        """Staging rows to launch for ``fill`` coalesced words: the next
        power-of-two super-tile count, capped at megabatch_tiles, so a
        ragged queue replays O(log megabatch_tiles) jit traces rather
        than one per fill level."""
        n_super = -(-fill // self.super_b)
        bucket = 1
        while bucket < n_super:
            bucket *= 2
        return min(bucket, self.megabatch_tiles) * self.super_b

    def _next_group(self) -> RetryGroup | None:
        """The next dispatchable group: an eligible retry first (FIFO),
        else a freshly coalesced one. Drops segments of requests that
        failed while their group waited."""
        now = time.monotonic()
        found, keep = None, []
        for grp in self._requeue:
            grp.segments = [(req, r0, take) for req, r0, take in grp.segments
                            if req.failure is None]
            if not grp.segments:
                continue                # everything in it already failed
            if found is None and grp.not_before <= now:
                found = grp
            else:
                keep.append(grp)
        self._requeue = keep
        if found is not None:
            return found
        segments = self._coalesce()
        return RetryGroup(segments) if segments else None

    def _fill_ring(self) -> int:
        """Dispatch until max_inflight launches are outstanding or
        nothing is dispatchable; returns the number of launches."""
        n = 0
        waited = False
        while len(self.ring) < self.max_inflight:
            grp = self._next_group()
            if grp is None:
                if self._requeue and not self.ring and not waited:
                    # every retryable group is backing off and nothing
                    # else is in flight: wait out the soonest backoff —
                    # once per tick, so a repeatedly failing group burns
                    # at most ~one retry per tick instead of sleeping
                    # through its whole quarantine budget here
                    wait = (min(g.not_before for g in self._requeue)
                            - time.monotonic())
                    if wait > 0:
                        time.sleep(wait)
                    waited = True
                    continue
                break
            n += self._dispatch_group(grp)
        return n

    def _launch_failed(self, grp: RetryGroup, exc: BaseException) -> int:
        """Shared failure path for dispatch errors, timeouts, and retire
        checksum mismatches: retry with backoff, bisect after
        ``max_retries``, quarantine single-request leaves."""
        if self.max_retries == 0:
            # strict mode: unwind the claims so every word is
            # re-coalesced from scratch, and propagate to the caller
            for req, _r0, take in grp.segments:
                req.dispatched -= take
            raise exc
        grp.retries += 1
        self.retries_total += 1
        self.events.emit("retry", attempt=grp.retries,
                         rids=[req.rid for req, _r0, _t in grp.segments],
                         detail=str(exc))
        if grp.retries > self.max_retries:
            if len(grp.segments) > 1:
                # the whole group keeps failing: split it so a poison
                # request is isolated in O(log segments) rounds while
                # the healthy halves complete
                mid = len(grp.segments) // 2
                self.bisections += 1
                self.events.emit("bisect", segments=len(grp.segments))
                self._requeue.append(RetryGroup(
                    grp.segments[:mid], via_megabatch=grp.via_megabatch))
                self._requeue.append(RetryGroup(
                    grp.segments[mid:], via_megabatch=grp.via_megabatch))
            else:
                (req, _r0, _take), = grp.segments
                req.failure = FailureInfo(
                    req.rid, "quarantined", retries=grp.retries,
                    detail=str(exc))
                self.quarantined += 1
        else:
            backoff = self.retry_backoff_s * (2 ** (grp.retries - 1))
            grp.not_before = time.monotonic() + backoff
            self._requeue.append(grp)
        return 0

    def _dispatch_group(self, grp: RetryGroup) -> int:
        """Launch one group; returns 1 on success, 0 when the failure
        was absorbed into the retry machinery."""
        from repro.kernels import ops  # lazy: keep engine import light

        if self.injector is not None:
            try:
                self.injector.on_dispatch(
                    rids=[req.rid for req, _r0, _take in grp.segments])
                if self._mesh is not None:
                    self.injector.on_device_loss()
            except Exception as e:
                if isinstance(e, DeviceLost):
                    self.device_losses += 1
                    self.events.emit("device_loss",
                                     data_devices=self.data_devices,
                                     detail=str(e))
                return self._launch_failed(grp, e)
        # one version per megabatch launch: recovered requests pin the
        # version they were admitted under, everything else serves the
        # current one (_coalesce never mixes pins in one group)
        pin = grp.segments[0][0].pin_version
        if pin is None:
            dv = self.store.acquire()
        else:
            try:
                dv = self.store.get(pin)
            except KeyError as e:
                # the pinned lexicon is gone from the catalog (snapshot
                # not restored / history dropped): fail loudly into the
                # retry machinery rather than silently serving another
                # version — auditability beats availability here
                return self._launch_failed(grp, e)
        handle = dv.handle
        if (self.residency_override is not None
                and handle.residency != self.residency_override):
            handle = self._degraded_handle(dv)
        use_persistent = self.persistent and not grp.via_megabatch
        slot = self._free_slots.pop()
        tile = self._staging[slot]
        placed, fill = [], 0
        for req, r0, take in grp.segments:
            tile[fill:fill + take] = req.words[r0:r0 + take]
            placed.append((req, r0, fill, take))
            fill += take
        rows = self._bucket_rows(fill)
        tile[fill:rows] = 0             # padded words must stay empty
        flags = checksums = None
        # with_checksum fuses the per-tile integrity row into the
        # launch's own jit scope (verified against a host recompute at
        # retire) — fault tolerance costs no extra XLA dispatch
        cs = self.checksum
        try:
            if self._mesh is not None:
                out = ops.extract_roots_sharded(
                    jnp.asarray(tile[:rows]), handle, self._mesh,
                    infix=self.infix, match=self.match, block_b=self.block_b,
                    dict_block_r=self.dict_block_r,
                    num_buffers=self.num_buffers, skip_index=self.skip_index,
                    with_checksum=cs, interpret=self.interpret)
                roots, sources = out[0], out[1]
            elif use_persistent:
                out = ops.extract_roots_persistent(
                    jnp.asarray(tile[:rows]), handle, infix=self.infix,
                    match=self.match, block_b=self.block_b,
                    dict_block_r=self.dict_block_r,
                    num_buffers=self.num_buffers, skip_index=self.skip_index,
                    version_slot=dv.version, with_checksum=cs,
                    interpret=self.interpret)
                roots, sources, flags = out[0], out[1], out[2]
            else:
                out = ops.extract_roots_fused(
                    jnp.asarray(tile[:rows]), handle, infix=self.infix,
                    match=self.match, block_b=self.block_b,
                    dict_block_r=self.dict_block_r,
                    num_buffers=self.num_buffers, skip_index=self.skip_index,
                    with_checksum=cs, interpret=self.interpret)
                roots, sources = out[0], out[1]
            if cs:
                checksums = out[-1]
        except BaseException as e:
            # a failed launch must not wedge the engine: return the slot
            # and route the group through the retry machinery (strict
            # mode re-raises with the words unclaimed)
            self._free_slots.append(slot)
            if isinstance(e, (KeyboardInterrupt, SystemExit)):
                raise
            return self._launch_failed(grp, e)
        entry = InflightTile(placed, dv.version, roots, sources, slot,
                             flags, checksums_dev=checksums,
                             retries=grp.retries,
                             t_dispatch=time.monotonic(),
                             via_megabatch=grp.via_megabatch)
        if flags is not None and self.injector is not None:
            # a wedge is observable only through the completion flags,
            # so the stall site covers persistent launches alone
            entry.stalled = self.injector.on_stall()
        try:                            # start D2H early; retire just reads
            roots.copy_to_host_async()
            sources.copy_to_host_async()
            if flags is not None:
                flags.copy_to_host_async()
            if checksums is not None:
                checksums.copy_to_host_async()
        except AttributeError:
            pass
        self.ring.append(entry)
        self.ticks_launched += 1
        return 1

    # -- retire side -------------------------------------------------------
    def _retire_ready(self) -> int:
        """Retire every in-flight launch whose results are ready (and
        abandon any past ``watchdog_s`` / ``launch_timeout_s``), oldest
        first, without blocking; returns the number processed."""
        still, n = [], 0
        now = time.monotonic()
        for entry in self.ring:
            stalled = entry.stalled is not None
            if not stalled and entry.is_ready():
                self._retire(entry)
                n += 1
            elif (self.watchdog_s is not None
                  and entry.flags_dev is not None
                  and now - entry.t_dispatch > self.watchdog_s):
                # persistent launch wedged: salvage the retired prefix,
                # re-dispatch the rest down the megabatch path
                self._watchdog_abandon(entry)
                n += 1
            elif (not stalled and self.launch_timeout_s is not None
                  and now - entry.t_dispatch > self.launch_timeout_s):
                # abandon the launch: drop the device refs, free the
                # slot, and re-dispatch its words through the retry path
                self.timeouts += 1
                self._free_slots.append(entry.slot)
                grp = RetryGroup([(req, r0, take) for req, r0, _t0, take
                                  in entry.segments], retries=entry.retries,
                                 via_megabatch=entry.via_megabatch)
                self._launch_failed(grp, TimeoutError(
                    f"launch exceeded launch_timeout_s="
                    f"{self.launch_timeout_s}"))
                n += 1
            else:
                still.append(entry)
        self.ring = still
        return n

    def _retire_blocking(self, entry: InflightTile) -> None:
        """Blocking drain of one launch. A launch marked wedged (an
        injected stall) must NOT be read — a real wedge never completes,
        and reading would block forever — so wait out the watchdog
        window and abandon it instead."""
        if entry.stalled is not None and self.watchdog_s is not None:
            wait = self.watchdog_s - (time.monotonic() - entry.t_dispatch)
            if wait > 0:
                time.sleep(wait)
            self._watchdog_abandon(entry)
        else:
            self._retire(entry)

    def _watchdog_abandon(self, entry: InflightTile) -> None:
        """Abandon a wedged persistent launch (DESIGN.md §12).

        Descriptors retire in ring order, so a wedge leaves a *prefix*
        of completion flags reading done: salvage that prefix (checksum-
        verified per tile), scatter its words, and re-dispatch the rest
        as a ``via_megabatch`` RetryGroup — never back into the wedged
        descriptor ring. No retry is charged: the stall is the launch's
        fault, not the group's, so zero requests are lost even at
        max_retries=0.
        """
        from repro.kernels import ops, stem_fused

        self.watchdog_stalls += 1
        self._free_slots.append(entry.slot)
        rows_ok = 0
        spec = entry.stalled
        if spec is not None:
            # injected wedge: the kernel actually completed (interpret
            # mode cannot truly hang), so synthesize the flag state a
            # real wedge would leave — the first `retired_tiles`
            # descriptors done, the rest untouched — then salvage
            flags = np.asarray(entry.flags_dev).copy()
            flags[min(spec.retired_tiles, flags.size):] = 0
            rows_ok = stem_fused.salvage_descriptor_rows(
                flags, entry.version, self.block_b)
        # a REAL wedge's arrays live in a launch that never completes;
        # reading them would block forever, so nothing is salvaged and
        # every word re-dispatches
        roots = sources = None
        if rows_ok > 0:
            roots = np.asarray(entry.roots_dev)[:rows_ok]
            sources = np.asarray(entry.sources_dev)[:rows_ok]
            if entry.checksums_dev is not None:
                want = np.asarray(
                    entry.checksums_dev)[:rows_ok // self.block_b]
                got = ops.tile_checksum_host(roots, sources,
                                             block_b=self.block_b)
                bad = np.flatnonzero(got != want)
                if bad.size:       # trust only the clean flag+sum prefix
                    rows_ok = int(bad[0]) * self.block_b
        salvaged = redispatched = 0
        redo = []
        for req, r0, t0, take in entry.segments:
            if req.failure is not None:   # expired/cancelled mid-flight
                continue
            good = max(0, min(take, rows_ok - t0))
            if good > 0:
                req.roots[r0:r0 + good] = roots[t0:t0 + good]
                req.sources[r0:r0 + good] = sources[t0:t0 + good]
                req.dict_versions[r0:r0 + good] = entry.version
                req.served += good
                salvaged += good
            if take > good:
                redo.append((req, r0 + good, take - good))
                redispatched += take - good
        if redo:
            self._requeue.append(RetryGroup(redo, retries=entry.retries,
                                            via_megabatch=True))
        self.events.emit("watchdog_stall", injected=spec is not None,
                         salvaged_words=salvaged,
                         redispatched_words=redispatched,
                         version=entry.version)

    def _retire(self, entry: InflightTile) -> bool:
        """Scatter one launch's results back (blocks if not yet ready).

        Returns False when the tile failed checksum verification and was
        re-queued for redispatch instead of scattered.
        """
        roots = np.asarray(entry.roots_dev)
        sources = np.asarray(entry.sources_dev)
        self._free_slots.append(entry.slot)
        if self.injector is not None:
            roots, sources = self.injector.on_retire(roots, sources)
        if entry.flags_dev is not None:
            # descriptor-ring integrity: every tile of the persistent
            # launch must have completed under the version pinned at
            # dispatch (flag = 1 + version slot; 0 = never processed)
            flags = np.asarray(entry.flags_dev)
            if not (flags == 1 + entry.version).all():
                raise RuntimeError(
                    "persistent launch retired with bad completion flags:"
                    f" expected {1 + entry.version}, got {flags.tolist()}")
        if entry.checksums_dev is not None:
            from repro.kernels import ops

            want = np.asarray(entry.checksums_dev)
            got = ops.tile_checksum_host(roots, sources,
                                         block_b=self.block_b)
            if not np.array_equal(got, want):
                bad = np.nonzero(got != want)[0].tolist()
                err = RuntimeError(
                    f"retire checksum mismatch on tile(s) {bad} of"
                    f" {want.shape[0]} (device vs host copy) — discarding"
                    " the launch")
                if self.max_retries == 0:
                    raise err
                self.checksum_failures += 1
                self.events.emit("checksum_failure", tiles=bad,
                                 rids=[req.rid for req, *_ in entry.segments])
                grp = RetryGroup([(req, r0, take) for req, r0, _t0, take
                                  in entry.segments], retries=entry.retries,
                                 via_megabatch=entry.via_megabatch)
                self._launch_failed(grp, err)
                return False
        for req, r0, t0, take in entry.segments:
            if req.failure is not None:   # expired/cancelled mid-flight
                continue
            req.roots[r0:r0 + take] = roots[t0:t0 + take]
            req.sources[r0:r0 + take] = sources[t0:t0 + take]
            req.dict_versions[r0:r0 + take] = entry.version
            req.served += take
        return True


# ---------------------------------------------------------------------------
# back-compat facade
# ---------------------------------------------------------------------------
class ServeEngine(Engine):
    """The original LM-serving entry point: Engine + LMDecodeWorkload.

    Construction signature and decode outputs are unchanged from the
    pre-refactor ServeEngine; run_until_drained now returns a
    DrainReport and (per the undrained-work fix) raises EngineUndrained
    instead of silently dropping queued requests at max_ticks.
    """

    def __init__(self, cfg, params, *, max_batch: int = 4,
                 cache_len: int = 128, greedy: bool = True):
        super().__init__(LMDecodeWorkload(cfg, params, max_batch=max_batch,
                                          cache_len=cache_len, greedy=greedy))


def _merge_slot(old, new, slot: int, batch: int | None = None):
    """Take slot `slot`'s rows from `new`, keep others from `old`.

    Cache layout: batch dim is index 1 ([L, B, ...]) except grouped VLM
    self-caches ([G, g, B, ...]) where it is index 2.
    """
    if batch is None:
        batch = max(x.shape[1] for x in jax.tree.leaves(new))

    def merge(o, n):
        if o.ndim >= 2 and o.shape[1] == batch:
            return o.at[:, slot].set(n[:, slot])
        return o.at[:, :, slot].set(n[:, :, slot])

    return jax.tree.map(merge, old, new)
