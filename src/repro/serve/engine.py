"""Serving engine: slot-based continuous batching over the jitted
prefill/decode steps.

Requests enter a fixed pool of B slots; prefill computes the prompt's KV
(state) which is spliced into the slot's region of the batched cache;
every engine step decodes one token for all live slots; finished slots
free immediately for the next queued request (continuous batching).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_mod


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32 [T] (or [T,K] audio)
    max_new: int = 16
    tokens_out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_batch: int = 4, cache_len: int = 128,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.cache_len = cache_len
        self.caches = model_mod.init_caches(cfg, max_batch, cache_len)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)   # next position
        self.queue: list[Request] = []
        self.finished: dict[int, Request] = {}
        self._next_rid = 0

        self._decode = jax.jit(
            lambda p, tok, caches, pos: model_mod.decode_step(
                p, cfg, tok, caches, pos))

    # -- client API --------------------------------------------------------
    def submit(self, prompt, max_new: int = 16) -> int:
        if max_new < 1:
            # prefill always emits the first generated token, so the engine
            # cannot return fewer than one token per request
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def result(self, rid: int) -> Request | None:
        return self.finished.get(rid)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # -- scheduling --------------------------------------------------------
    def _admit(self):
        for slot in range(self.B):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into_slot(slot, req)

    def _prefill_into_slot(self, slot: int, req: Request):
        """Prompt tokens run through decode steps into this slot's cache.

        (Single-slot prefill-by-decode keeps the engine simple and exactly
        consistent with the decode path; bulk prefill would jit
        forward(mode='prefill') and splice — see launch/serve.py.)
        """
        self.slot_req[slot] = req
        self.slot_pos[slot] = 0
        for t, tok in enumerate(req.prompt[:-1]):
            self._step_slot(slot, int(tok), emit=False)
        # last prompt token emits the first generated token
        self._step_slot(slot, int(req.prompt[-1]), emit=True)

    def _step_slot(self, slot: int, token: int, emit: bool):
        cfg = self.cfg
        tok_shape = (self.B, 1, cfg.n_codebooks) if cfg.n_codebooks else (self.B, 1)
        toks = np.zeros(tok_shape, np.int32)
        toks[slot] = token
        pos = jnp.int32(int(self.slot_pos[slot]))
        logits, new_caches = self._decode(self.params, jnp.asarray(toks),
                                          self.caches, pos)
        # merge only this slot's cache rows (positions differ per slot)
        self.caches = _merge_slot(self.caches, new_caches, slot, batch=self.B)
        self.slot_pos[slot] += 1
        if emit:
            req = self.slot_req[slot]
            nxt = int(np.asarray(jnp.argmax(logits[slot, -1], axis=-1)).reshape(-1)[0])
            req.tokens_out.append(nxt)

    def _finish_slot(self, slot: int, req: Request):
        req.done = True
        self.finished[req.rid] = req
        self.slot_req[slot] = None

    def step(self):
        """One engine tick: admit from queue, decode all live slots.

        Doneness is checked BEFORE decoding: a request admitted this tick
        already holds its prefill-emitted token, so with max_new=1 it must
        free its slot without an extra decode (it would otherwise return
        max_new + 1 tokens).
        """
        self._admit()
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None:
                continue
            if len(req.tokens_out) >= req.max_new:
                self._finish_slot(slot, req)
                continue
            self._step_slot(slot, req.tokens_out[-1], emit=True)
            if len(req.tokens_out) >= req.max_new:
                self._finish_slot(slot, req)

    def run_until_drained(self, max_ticks: int = 1000):
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks


def _merge_slot(old, new, slot: int, batch: int | None = None):
    """Take slot `slot`'s rows from `new`, keep others from `old`.

    Cache layout: batch dim is index 1 ([L, B, ...]) except grouped VLM
    self-caches ([G, g, B, ...]) where it is index 2.
    """
    if batch is None:
        batch = max(x.shape[1] for x in jax.tree.leaves(new))

    def merge(o, n):
        if o.ndim >= 2 and o.shape[1] == batch:
            return o.at[:, slot].set(n[:, slot])
        return o.at[:, :, slot].set(n[:, :, slot])

    return jax.tree.map(merge, old, new)
