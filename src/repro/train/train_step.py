"""The jitted training step: loss -> grads (remat, microbatched) ->
clipped AdamW update. Factory-style so the distribution layer can inject
sharding constraints and the dry-run can lower it AOT."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as model_mod
from repro.train import optimizer


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    raise ValueError(f"unknown remat policy {name!r}")


def make_train_step(cfg, run, mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    cst = None
    if mesh is not None:
        from repro.dist import sharding

        cst = sharding.make_constrain(mesh, run.profile)
    policy = remat_policy(run.remat)
    use_remat = run.remat != "none"

    def loss(params, batch):
        return model_mod.loss_fn(
            params, cfg, batch, constrain=cst,
            remat_policy=policy if use_remat else None)

    def grads_fn(params, batch):
        if run.microbatches <= 1:
            return jax.value_and_grad(loss)(params, batch)

        m = run.microbatches

        def split(x):
            return x.reshape(m, x.shape[0] // m, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, mb_i):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss)(params, mb_i)
            acc_g = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), acc_g, g)
            return (acc_loss + l, acc_g), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (total_l, total_g), _ = jax.lax.scan(body, (0.0, zero_g), mb)
        scale = 1.0 / m
        return total_l * scale, jax.tree.map(lambda g: g * scale, total_g)

    def train_step(params, opt_state, batch):
        l, grads = grads_fn(params, batch)
        lr = optimizer.cosine_lr(opt_state.step, peak=run.learning_rate,
                                 warmup=run.lr_warmup)
        params, opt_state, metrics = optimizer.update(
            params, grads, opt_state, lr=lr,
            weight_decay=run.weight_decay, clip=run.grad_clip)
        metrics["loss"] = l
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, mesh=None, profile: str = "default"):
    cst = None
    if mesh is not None:
        from repro.dist import sharding

        cst = sharding.make_constrain(mesh, profile)

    def prefill_step(params, tokens, vision_embeds=None):
        out = model_mod.forward(params, cfg, tokens, mode="prefill",
                                vision_embeds=vision_embeds, constrain=cst)
        return out.logits[:, -1:], out.caches

    return prefill_step


def make_decode_step(cfg, mesh=None, profile: str = "default"):
    cst = None
    if mesh is not None:
        from repro.dist import sharding

        cst = sharding.make_constrain(mesh, profile)

    def decode_step(params, tokens, caches, pos):
        return model_mod.decode_step(params, cfg, tokens, caches, pos,
                                     constrain=cst)

    return decode_step
