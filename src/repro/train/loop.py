"""Training loop with checkpoint-restart fault tolerance, preemption
handling, straggler detection hooks, and async checkpointing off the
critical path."""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax

from repro.train import checkpoint, optimizer, train_step as ts


@dataclass
class FitResult:
    steps_run: int
    final_step: int
    losses: list = field(default_factory=list)
    resumed_from: int | None = None
    straggler_events: int = 0


def fit(cfg, run, data_iter, *, params=None, steps: int = 100,
        ckpt_dir=None, ckpt_every: int = 50, mesh=None, seed: int = 0,
        step_timeout_factor: float = 3.0, on_metrics=None) -> FitResult:
    """Run (or resume) a training job.

    Fault tolerance:
      - resumes from the latest COMMITTED checkpoint in ckpt_dir;
      - SIGTERM (preemption) triggers a synchronous checkpoint + clean exit;
      - per-step wall-time watchdog counts straggler events (steps slower
        than step_timeout_factor x the running median) — on a real cluster
        this feeds the coordinator's replace-node decision.
    """
    from repro.models import model as model_mod
    from repro.models import params as pm

    step_fn = ts.make_train_step(cfg, run, mesh)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    if params is None:
        params = pm.init_params(model_mod.model_spec(cfg), jax.random.key(seed))
    opt_state = optimizer.init(params)

    start_step = 0
    resumed = None
    if ckpt_dir is not None:
        latest = checkpoint.latest_step(ckpt_dir)
        if latest is not None:
            state = {"params": params, "opt": opt_state}
            state = checkpoint.restore(ckpt_dir, latest, state)
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            resumed = latest

    preempted = {"flag": False}

    def _on_term(signum, frame):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _on_term)

    result = FitResult(steps_run=0, final_step=start_step, resumed_from=resumed)
    durations: list[float] = []
    pending_ckpt = None
    try:
        for step in range(start_step, steps):
            batch = next(data_iter)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)
            med = sorted(durations)[len(durations) // 2]
            if len(durations) > 5 and dt > step_timeout_factor * med:
                result.straggler_events += 1
            result.losses.append(loss)
            result.steps_run += 1
            result.final_step = step + 1
            if on_metrics:
                on_metrics(step, metrics)

            want_ckpt = ckpt_dir is not None and (
                (step + 1) % ckpt_every == 0 or preempted["flag"])
            if want_ckpt:
                if pending_ckpt is not None:
                    pending_ckpt.join()
                pending_ckpt = checkpoint.save(
                    ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                    async_=not preempted["flag"])
            if preempted["flag"]:
                break
    finally:
        if pending_ckpt is not None:
            pending_ckpt.join()
        signal.signal(signal.SIGTERM, old_handler)
    return result
