"""Fault-tolerant checkpointing: atomic, sharded, async, multi-host aware.

Layout:
  <dir>/step_<n>/manifest.json        tree structure + shapes + dtypes
  <dir>/step_<n>/proc_<k>.npz         this process's addressable shards
  <dir>/step_<n>/COMMITTED            written last — restart-safe marker

Restores re-shard automatically: arrays are device_put against the
*target* shardings (which may come from a different mesh than the one
that saved — elastic up/down-scaling reuses this path, see
train/elastic.py).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(p) for p in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(ckpt_dir, step: int, tree, *, keep: int = 3, async_: bool = False):
    """Save a pytree of jax arrays. Returns a Thread when async_."""
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:08d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:08d}"

    keys, vals, _ = _flatten(tree)
    # snapshot to host memory synchronously (cheap); IO goes async
    host_vals = [np.asarray(jax.device_get(v)) for v in vals]

    def _write():
        tmp_dir.mkdir(parents=True, exist_ok=True)
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": [list(v.shape) for v in host_vals],
            "dtypes": [str(v.dtype) for v in host_vals],
            "n_processes": jax.process_count(),
        }
        (tmp_dir / "manifest.json").write_text(json.dumps(manifest))
        np.savez(tmp_dir / f"proc_{jax.process_index()}.npz",
                 **{f"a{i}": v for i, v in enumerate(host_vals)})
        (tmp_dir / "COMMITTED").write_text("ok")
        if step_dir.exists():
            shutil.rmtree(step_dir)
        tmp_dir.rename(step_dir)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(d for d in ckpt_dir.glob("step_*") if (d / "COMMITTED").exists())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(d.name.split("_")[1])
        for d in ckpt_dir.glob("step_*")
        if (d / "COMMITTED").exists()
    ]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, target_tree, shardings=None):
    """Restore into the structure of `target_tree` (values ignored).

    shardings: optional matching pytree of Shardings for resharded
    placement (elastic restarts across different meshes).
    """
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    if not (step_dir / "COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    manifest = json.loads((step_dir / "manifest.json").read_text())
    data = np.load(step_dir / f"proc_{jax.process_index()}.npz")
    vals = [data[f"a{i}"] for i in range(len(manifest["keys"]))]

    keys, _, treedef = _flatten(target_tree)
    if keys != manifest["keys"]:
        raise ValueError("checkpoint tree structure mismatch")
    if shardings is not None:
        sh_flat = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        vals = [jax.device_put(v, s) for v, s in zip(vals, sh_flat)]
    else:
        vals = [jax.device_put(v) for v in vals]
    return jax.tree_util.tree_unflatten(treedef, vals)
