"""repro.train subpackage."""
