"""AdamW with global-norm clipping, cosine schedule, and configurable
moment dtype (bf16 moments keep the 235B-MoE optimizer inside v5e HBM —
see EXPERIMENTS §Dry-run). Optimizer state inherits parameter shardings
(params are already FSDP+TP sharded, i.e. ZeRO-3-style)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params, moments_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moments_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def abstract_state(abstract_params, moments_dtype=jnp.float32) -> AdamWState:
    """ShapeDtypeStruct mirror of init() for AOT lowering."""
    z = lambda p: jax.ShapeDtypeStruct(p.shape, moments_dtype,
                                       sharding=getattr(p, "sharding", None))
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(z, abstract_params),
        v=jax.tree.map(z, abstract_params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_lr(step, *, peak: float, warmup: int = 100, total: int = 10000,
              floor: float = 0.1):
    warm = peak * (step + 1) / warmup
    frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def update(params, grads, state: AdamWState, *, lr, weight_decay=0.1,
           b1=0.9, b2=0.95, eps=1e-8, clip=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    step = state.step + 1
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        delta = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps) + weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(m.dtype),
            v32.astype(v.dtype),
        )

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm,
        "clip_scale": scale,
    }
