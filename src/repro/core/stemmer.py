"""Vectorised (batch-parallel) JAX implementation of the paper's stemmer.

The five FPGA pipeline stages (Fig 10) map onto tensor stages over a batch
of encoded words ``int32[B, 16]``:

  stage 1  Check Prefixes / Check Suffixes  -> broadcast membership tests
  stage 2  Produce Prefixes / Suffixes      -> anchored cumulative-AND runs
  stage 3  Generate Stems                   -> static 6x2 (prefix-cut x size)
                                               truncation grid (VHDL Fig 12)
  stage 4  Filter by Size                   -> implicit in the static grid
  stage 5  Compare Stems & Extract Root     -> dictionary match (dense /
                                               sorted-search / Pallas kernel)
                                               + priority select

Candidate grid: a stem is word[p+1 : p+1+L] for prefix cut p in {-1..4} and
L in {3, 4}; the suffix cut is determined as s = p+1+L. 6 trilateral + 6
quadrilateral candidates per word, matching the VHDL's 6-slot arrays (the
``count1 < 5`` cap never binds — see DESIGN.md).

Infix processing (paper §6.3) adds three recovery candidate groups:
restored hollow (ا→و), remove-infix quad→tri, remove-infix tri→bi.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import pyref

# candidate-group tags == pyref source tags
N_CAND = 6  # prefix cuts -1..4


@jax.tree_util.register_pytree_node_class
@dataclass
class RootDictArrays:
    """Packed, sorted root dictionaries (int32 keys; see alphabet.pack_key)."""

    tri: jnp.ndarray   # int32[Rt] sorted
    quad: jnp.ndarray  # int32[Rq] sorted
    bi: jnp.ndarray    # int32[Rb] sorted

    def tree_flatten(self):
        return (self.tri, self.quad, self.bi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def from_rootdict(d: pyref.RootDict) -> "RootDictArrays":
        def pack(roots):
            keys = sorted(ab.pack_key(r) for r in roots) or [-1]
            return jnp.asarray(np.asarray(keys, np.int32))

        return RootDictArrays(tri=pack(d.tri), quad=pack(d.quad), bi=pack(d.bi))

    @property
    def n_keys(self) -> int:
        return sum(int(d.shape[0]) for d in (self.tri, self.quad, self.bi))


@jax.tree_util.register_pytree_node_class
@dataclass
class ResolvedRootDict:
    """A RootDictArrays plus its *pre-resolved* megakernel configuration.

    Serving hot-swaps dictionaries between tile launches (see
    serve/dict_store.py); resolving ``residency="auto"`` once at publish
    time pins the kernel's static configuration, so a swap whose arrays
    keep their shapes replays the existing jit trace instead of
    re-tracing. The residency rides as pytree aux data: two handles with
    equal shapes and equal residency hit the same cache entry.

    ``tiles`` optionally carries a prebuilt ``stem_match.DictTileSet``
    for the streamed layout: the padded `[tri | quad | bi]` tile stream
    plus the per-tile sorted boundary tables the tile-visit pre-pass
    intersects candidate keys against. Publishing with a ``dict_block_r``
    precomputes it once, so serving launches (and hot swaps) skip the
    per-call pad/concat of the dictionary stream.

    Every stemmer entry point (``extract_roots``/``stem_batch``/... and
    ``ops.extract_roots_fused``) accepts a handle anywhere it accepts
    plain arrays; the handle's pinned residency wins over the call-site
    default ("auto"), and conflicting explicit residencies raise.
    """

    arrays: RootDictArrays
    residency: str          # "resident" | "streamed" — never "auto"
    tiles: object = None    # stem_match.DictTileSet | None (streamed layout)

    def tree_flatten(self):
        return (self.arrays, self.tiles), self.residency

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux, children[1])

    @property
    def n_keys(self) -> int:
        return self.arrays.n_keys


def resolve_dict(roots, *, residency: str = "auto", infix: bool = True,
                 dict_block_r: int | None = None) -> ResolvedRootDict:
    """Pin a dictionary's residency against the VMEM budget once, up front.

    ``infix`` scopes the budget to the tables the sweep loads (bi never
    ships for infix=False). A streamed resolution with ``dict_block_r``
    set also prebuilds the ``DictTileSet`` (tile stream + boundary
    tables), so every later launch — including shape-matched hot swaps —
    reuses it instead of re-padding the tables per call.
    """
    if isinstance(roots, ResolvedRootDict):
        unwrap_dict(roots, residency)  # conflicting residency raises
        res, arrays = roots.residency, roots.arrays
    else:
        from repro.kernels import stem_fused as sf  # lazy: kernels need core

        res = sf.choose_residency(roots, residency, infix=infix)
        arrays = roots
    tiles = roots.tiles if isinstance(roots, ResolvedRootDict) else None
    if res == "streamed" and dict_block_r and (
            tiles is None or tiles.dict_block_r != dict_block_r):
        # an already-resolved handle without (matching) tiles still gets
        # them built here — publish-time prebuild must not silently skip
        from repro.kernels import stem_match as smm

        tiles = smm.build_dict_tiles(arrays.tri, arrays.quad, arrays.bi,
                                     dict_block_r)
    if isinstance(roots, ResolvedRootDict) and tiles is roots.tiles:
        return roots
    return ResolvedRootDict(arrays, res, tiles)


def unwrap_dict(roots, residency: str = "auto"):
    """-> (RootDictArrays, residency, tiles); a handle's pinned residency
    wins, tiles is the handle's prebuilt DictTileSet (None otherwise)."""
    if isinstance(roots, ResolvedRootDict):
        if residency not in ("auto", roots.residency):
            raise ValueError(
                f"residency={residency!r} conflicts with the resolved dict"
                f" handle's pinned residency {roots.residency!r}")
        return roots.arrays, roots.residency, roots.tiles
    return roots, residency, None


# ---------------------------------------------------------------------------
# Stages 1-2
# ---------------------------------------------------------------------------
def check_and_produce(words: jnp.ndarray):
    """words int32[B,16] -> (pp bool[B,5], valid_s bool[B,17], n int32[B])."""
    prefix_codes = jnp.asarray(ab.PREFIX_CODES)
    suffix_codes = jnp.asarray(ab.SUFFIX_CODES)
    in_word = words != 0
    n = in_word.sum(axis=-1).astype(jnp.int32)

    head = words[:, :5]
    is_pref = (head[..., None] == prefix_codes).any(-1)
    run = jnp.cumprod(is_pref.astype(jnp.int32), axis=1) > 0
    yeh = head == ab.YEH
    yeh_before = jnp.cumsum(yeh.astype(jnp.int32), axis=1) - yeh
    pp = run & (yeh_before == 0)

    is_suf = (words[..., None] == suffix_codes).any(-1)
    ok = is_suf | ~in_word                      # pads don't break the run
    rev = jnp.flip(jnp.cumprod(jnp.flip(ok, 1).astype(jnp.int32), 1), 1) > 0
    ps = rev & in_word                          # bool[B,16]

    s_grid = jnp.arange(ab.MAXLEN + 1, dtype=jnp.int32)  # 0..16
    ps_pad = jnp.pad(ps, ((0, 0), (0, 1)))
    valid_s = (s_grid[None, :] == n[:, None]) | (
        (s_grid[None, :] < n[:, None]) & ps_pad
    )
    return pp, valid_s, n


# ---------------------------------------------------------------------------
# Stages 3-4
# ---------------------------------------------------------------------------
def generate_stems(words: jnp.ndarray):
    """-> (tri int32[B,6,4] zero-padded, tri_valid, quad int32[B,6,4], quad_valid).

    Candidate order along axis 1 is prefix cut p = -1, 0, 1, 2, 3, 4 — the
    VHDL loop order, which also defines match priority.
    """
    pp, valid_s, _ = check_and_produce(words)
    tri_list, quad_list, tv_list, qv_list = [], [], [], []
    for p in range(-1, 5):
        start = p + 1
        p_ok = jnp.ones(words.shape[0], bool) if p == -1 else pp[:, p]
        tri_chars = jax.lax.slice_in_dim(words, start, start + 3, axis=1)
        tri_list.append(jnp.pad(tri_chars, ((0, 0), (0, 1))))
        tv_list.append(p_ok & valid_s[:, p + 4])
        quad_chars = jax.lax.slice_in_dim(words, start, start + 4, axis=1)
        quad_list.append(quad_chars)
        qv_list.append(p_ok & valid_s[:, p + 5])
    tri = jnp.stack(tri_list, axis=1)
    quad = jnp.stack(quad_list, axis=1)
    tri_valid = jnp.stack(tv_list, axis=1)
    quad_valid = jnp.stack(qv_list, axis=1)
    return tri, tri_valid, quad, quad_valid


def pack_keys(stems: jnp.ndarray) -> jnp.ndarray:
    """int32[..., 4] char codes -> int32[...] packed 24-bit keys."""
    c = stems.astype(jnp.int32)
    return ((c[..., 0] * 64 + c[..., 1]) * 64 + c[..., 2]) * 64 + c[..., 3]


# ---------------------------------------------------------------------------
# Stage 5 backends
# ---------------------------------------------------------------------------
def match_dense(keys: jnp.ndarray, dict_keys: jnp.ndarray) -> jnp.ndarray:
    """O(N*R) broadcast compare — the paper's baseline Compare process."""
    return (keys[..., None] == dict_keys).any(-1)


def match_sorted(keys: jnp.ndarray, dict_keys: jnp.ndarray) -> jnp.ndarray:
    """O(N log R) binary search — the paper's proposed tree-search upgrade."""
    idx = jnp.searchsorted(dict_keys, keys)
    idx = jnp.clip(idx, 0, dict_keys.shape[0] - 1)
    return dict_keys[idx] == keys


def _match(keys, dict_keys, backend: str):
    if backend == "dense":
        return match_dense(keys, dict_keys)
    if backend == "sorted":
        return match_sorted(keys, dict_keys)
    if backend in ("pallas", "fused"):
        from repro.kernels import ops  # lazy: kernels depend on core

        # "fused" reaching stage 5 in isolation (e.g. through the extended
        # rule pool) uses the megakernel's in-kernel sorted search.
        strategy = "bsearch" if backend == "fused" else "bank"
        shape = keys.shape
        return ops.dict_match(
            keys.reshape(-1), dict_keys, strategy=strategy).reshape(shape)
    raise ValueError(f"unknown match backend: {backend}")


# ---------------------------------------------------------------------------
# Full extraction
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("infix", "backend", "extended",
                                             "residency", "num_buffers",
                                             "skip_index"))
def extract_roots(
    words: jnp.ndarray,
    roots: RootDictArrays,
    *,
    infix: bool = True,
    backend: str = "sorted",
    extended: bool = False,
    residency: str = "auto",
    num_buffers: int = 2,
    skip_index: bool = True,
):
    """words int32[B,16] -> (root int32[B,4], source int32[B]).

    source uses pyref.SRC_* tags; root rows are zero-padded char codes.
    extended=True adds the beyond-paper rule pool (final ى→ي, hollow ا→ي).
    roots may be plain RootDictArrays or a ResolvedRootDict handle whose
    pinned residency then overrides the residency argument.

    backend selects the Compare stage implementation: "dense" / "sorted"
    (pure jnp), "pallas" (tiled comparator-bank kernel) or "fused" — the
    single-launch stage 1-5 megakernel (kernels/stem_fused.py;
    paper-exact, no intermediate HBM tensors). For the fused backend,
    residency picks the dictionary layout: "resident" (VMEM-held),
    "streamed" (a scalar-prefetched tile-visit sweep fed by an explicit
    DMA ladder — unbounded dictionary size), or "auto" (default:
    resident while it fits); ``num_buffers`` (DMA ladder depth) and
    ``skip_index`` (visit only tiles that can hit) tune the streamed
    sweep and are ignored elsewhere. The extended rule pool is not in
    the megakernel's candidate grid, so extended=True keeps the staged
    path and uses the megakernel's in-kernel sorted search for stage 5
    only.
    """
    if backend == "fused" and not extended:
        from repro.kernels import ops  # lazy: kernels depend on core

        # pass roots through unchanged: a ResolvedRootDict handle keeps
        # its pinned residency and prebuilt tile stream
        return ops.extract_roots_fused(words, roots, infix=infix,
                                       residency=residency,
                                       num_buffers=num_buffers,
                                       skip_index=skip_index)

    roots, residency, _ = unwrap_dict(roots, residency)
    tri, tri_valid, quad, quad_valid = generate_stems(words)
    infix_codes = jnp.asarray(ab.INFIX_CODES)

    groups = []  # (stems[B,6,4], valid[B,6], dict, src_tag)
    groups.append((tri, tri_valid, roots.tri, pyref.SRC_TRI))
    groups.append((quad, quad_valid, roots.quad, pyref.SRC_QUAD))
    if infix:
        restored = tri.at[..., 1].set(
            jnp.where(tri[..., 1] == ab.ALEF, ab.WAW, tri[..., 1])
        )
        r_valid = tri_valid & (tri[..., 1] == ab.ALEF)
        groups.append((restored, r_valid, roots.tri, pyref.SRC_RESTORED))

        is_inf_q = (quad[..., 1:2] == infix_codes).any(-1)
        deinf_q = jnp.stack(
            [quad[..., 0], quad[..., 2], quad[..., 3], jnp.zeros_like(quad[..., 0])],
            axis=-1,
        )
        groups.append((deinf_q, quad_valid & is_inf_q, roots.tri, pyref.SRC_DEINFIX_TRI))

        is_inf_t = (tri[..., 1:2] == infix_codes).any(-1)
        deinf_t = jnp.stack(
            [tri[..., 0], tri[..., 2], jnp.zeros_like(tri[..., 0]),
             jnp.zeros_like(tri[..., 0])],
            axis=-1,
        )
        groups.append((deinf_t, tri_valid & is_inf_t, roots.bi, pyref.SRC_DEINFIX_BI))

    if extended:  # beyond-paper rule pool (paper §7 future work)
        defect = tri.at[..., 2].set(
            jnp.where(tri[..., 2] == pyref.ALEF_MAQSURA, ab.YEH, tri[..., 2]))
        d_valid = tri_valid & (tri[..., 2] == pyref.ALEF_MAQSURA)
        groups.append((defect, d_valid, roots.tri, pyref.SRC_EXT_DEFECTIVE))

        hollow_y = tri.at[..., 1].set(
            jnp.where(tri[..., 1] == ab.ALEF, ab.YEH, tri[..., 1]))
        hy_valid = tri_valid & (tri[..., 1] == ab.ALEF)
        groups.append((hollow_y, hy_valid, roots.tri, pyref.SRC_EXT_HOLLOW_Y))

    all_stems = jnp.concatenate([g[0] for g in groups], axis=1)   # [B, 6G, 4]
    all_valid = jnp.concatenate([g[1] for g in groups], axis=1)   # [B, 6G]
    # One fused match per dictionary (tri dict serves groups 1/3/4).
    hits = []
    for stems, valid, dict_keys, _src in groups:
        keys = pack_keys(stems)
        hits.append(_match(keys, dict_keys, backend) & valid)
    all_hits = jnp.concatenate(hits, axis=1)

    first = jnp.argmax(all_hits, axis=1)                          # first True
    found = all_hits.any(axis=1)
    root = jnp.take_along_axis(all_stems, first[:, None, None], axis=1)[:, 0]
    root = jnp.where(found[:, None], root, 0)
    src_tags = jnp.asarray(
        np.repeat([g[3] for g in groups], N_CAND).astype(np.int32)
    )
    source = jnp.where(found, src_tags[first], pyref.SRC_NONE)
    return root, source


# ---------------------------------------------------------------------------
# The paper's three execution models — contract-identical signatures: each
# accepts the full (infix, backend, extended, residency) option set.
# ---------------------------------------------------------------------------
def stem_batch(words, roots, *, infix=True, backend="sorted", extended=False,
               residency="auto", num_buffers=2, skip_index=True):
    """'Non-pipelined processor' analogue: whole batch through all stages."""
    return extract_roots(words, roots, infix=infix, backend=backend,
                         extended=extended, residency=residency,
                         num_buffers=num_buffers, skip_index=skip_index)


@functools.partial(jax.jit, static_argnames=("infix", "backend", "extended",
                                             "residency", "num_buffers",
                                             "skip_index"))
def stem_sequential(words, roots, *, infix=True, backend="sorted",
                    extended=False, residency="auto", num_buffers=2,
                    skip_index=True):
    """'Software implementation' analogue: one word at a time (lax.scan)."""

    def step(carry, w):
        r, s = extract_roots(w[None], roots, infix=infix, backend=backend,
                             extended=extended, residency=residency,
                             num_buffers=num_buffers, skip_index=skip_index)
        return carry, (r[0], s[0])

    _, (root, source) = jax.lax.scan(step, 0, words)
    return root, source


def stem_pipelined(words, roots, *, infix=True, backend="sorted",
                   extended=False, residency="auto", num_buffers=2,
                   skip_index=True, microbatch=256):
    """'Pipelined processor' analogue on one host: microbatched streaming.

    On real hardware the per-microbatch stages overlap via async dispatch;
    across devices use repro.dist.pipeline.pipeline_map. Numerically
    identical to stem_batch.
    """
    b = words.shape[0]
    pad = (-b) % microbatch
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    chunks = wp.reshape(-1, microbatch, words.shape[1])
    outs = [stem_batch(c, roots, infix=infix, backend=backend,
                       extended=extended, residency=residency,
                       num_buffers=num_buffers, skip_index=skip_index)
            for c in chunks]
    root = jnp.concatenate([o[0] for o in outs])[:b]
    source = jnp.concatenate([o[1] for o in outs])[:b]
    return root, source
