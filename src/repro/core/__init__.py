"""Core: the paper's verb-root-extraction stemmer (see DESIGN.md §1-2).

Modules:
  alphabet   — codepoint tables, normalisation, dense 6-bit packing
  pyref      — pure-Python oracle (executable spec)
  stemmer    — vectorised JAX implementation (5 stages, 3 match backends)
  conjugator — verb-form generator (corpus synthesis)
  corpus     — root dictionaries + synthetic Zipf corpus
  accuracy   — Tables 6/7 analogue harness
"""
