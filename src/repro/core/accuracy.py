"""Accuracy analysis harness (paper Tables 6 & 7 analogue)."""
from __future__ import annotations

from collections import Counter, defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.core import alphabet as ab
from repro.core import corpus as corpus_mod
from repro.core import pyref, stemmer


@dataclass
class AccuracyReport:
    total: int = 0
    correct: int = 0
    by_source: Counter = field(default_factory=Counter)
    per_root: dict = field(default_factory=dict)  # root -> (actual, correct)

    @property
    def accuracy(self) -> float:
        """Word-level accuracy (stricter than the paper's measure)."""
        return self.correct / max(1, self.total)

    @property
    def root_recall(self) -> float:
        """The paper's Table-6 measure: fraction of distinct ground-truth
        roots successfully extracted at least once anywhere in the corpus
        (1549/1767 = 87.7% with infix processing in the paper)."""
        hit = sum(1 for a, c in self.per_root.values() if c > 0)
        return hit / max(1, len(self.per_root))


def _root_matches(pred_codes, pred_src: int, truth: str) -> bool:
    pred = ab.decode_word(pred_codes)
    if pred == truth:
        return True
    # A bilateral extraction matches a geminated trilateral truth (مد ≡ مدد)
    if pred_src == pyref.SRC_DEINFIX_BI and len(pred) == 2:
        return truth in (pred + pred[1], pred)
    return False


def evaluate(
    words: list[str],
    truths: list[str],
    roots: pyref.RootDict,
    *,
    infix: bool = True,
    backend: str = "sorted",
    extended: bool = False,
    batch: int = 4096,
) -> AccuracyReport:
    enc = corpus_mod.encode_corpus(words)
    dict_arrays = stemmer.RootDictArrays.from_rootdict(roots)
    rep = AccuracyReport()
    per_root = defaultdict(lambda: [0, 0])
    for i in range(0, len(words), batch):
        chunk = enc[i : i + batch]
        pred_roots, pred_src = stemmer.stem_batch(
            chunk, dict_arrays, infix=infix, backend=backend, extended=extended
        )
        pred_roots = np.asarray(pred_roots)
        pred_src = np.asarray(pred_src)
        for j in range(chunk.shape[0]):
            truth = truths[i + j]
            ok = _root_matches(pred_roots[j], int(pred_src[j]), truth)
            rep.total += 1
            rep.correct += int(ok)
            rep.by_source[int(pred_src[j])] += 1
            per_root[truth][0] += 1
            per_root[truth][1] += int(ok)
    rep.per_root = {r: tuple(v) for r, v in per_root.items()}
    return rep


def table6(n_words: int = 20000, seed: int = 0, backend: str = "sorted"):
    """Accuracy with vs without infix processing (paper Table 6)."""
    words, truths, _ = corpus_mod.build_corpus(n_words, seed)
    roots = corpus_mod.build_dictionary()
    with_infix = evaluate(words, truths, roots, infix=True, backend=backend)
    without = evaluate(words, truths, roots, infix=False, backend=backend)
    return {"with_infix": with_infix, "without_infix": without}


def table7(n_words: int = 20000, seed: int = 0, top_k: int = 10):
    """Per-root accuracy for the highest-frequency roots (paper Table 7)."""
    words, truths, _ = corpus_mod.build_corpus(n_words, seed)
    roots = corpus_mod.build_dictionary()
    rep_with = evaluate(words, truths, roots, infix=True)
    rep_wo = evaluate(words, truths, roots, infix=False)
    freq = Counter(truths)
    rows = []
    for root, actual in freq.most_common(top_k):
        w = rep_with.per_root.get(root, (0, 0))
        wo = rep_wo.per_root.get(root, (0, 0))
        rows.append(
            {
                "root": root,
                "actual": actual,
                "with_infix": w[1],
                "without_infix": wo[1],
            }
        )
    return rows
