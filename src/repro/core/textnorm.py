"""Raw-text normalisation + segmentation rules: the shared single source
of truth for the text ingestion front-end (DESIGN.md §7).

Three implementations consume the tables defined here and must agree
bit-for-bit on every document:

  host reference   ``analyze_text_py`` — plain python over strings; the
                   independent oracle the parity tests trust
  jnp reference    ``frontend_reference`` — scatter-based, whole-tile
                   vectorised; what the Pallas kernel must match
  Pallas kernel    ``kernels/text_frontend.py`` — gather-based, one grid
                   step per [block_w] word tile, sharing
                   :func:`strip_and_pack` with the jnp reference (the
                   ``candidate_columns`` precedent: one jnp datapath body
                   traced both standalone and inside the kernel)

The rule pipeline (SNIPPETS.md Snippet 1, ``alif/sentence_validator``):

  classify    every codepoint is a LETTER (dense 6-bit code with
              normalisation baked in: alef variants -> ا, ة -> ت), a
              MARK (diacritics + tatweel: deleted in place, never
              splits a word), or a SEPARATOR (whitespace, punctuation,
              digits, anything non-Arabic — including the 0 pad)
  segment     words are maximal runs of non-separator codepoints;
              each word records its [start, end) utf-8 byte span
  strip       one longest-match proclitic (و ف ب ل ك | لل | وال بال
              فال كال) and one longest-match enclitic (ه ك | ها هم هن
              كم كن نا ني | هما), each only if >= MIN_STEM letters
              remain — EXCEPT for function words: a word whose
              normalised form is in FUNCTION_WORDS is never stripped
              (كانت is the verb "she was", not ك + انت "like you")
  pack        first 15 letters -> the [16] word-tile row the stemmer
              megakernel consumes

Fixed windows keep all three implementations identical on degenerate
input: at most MAX_RAW raw codepoints of a word are examined and at most
CMAX normalised letters kept before stripping, so a 100-codepoint "word"
truncates the same way in a python loop, a jnp scatter, and the kernel's
fixed-size gather.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab

# ---------------------------------------------------------------------------
# classes + windows
# ---------------------------------------------------------------------------
CLS_SEP = 0       # separator (also the 0 pad codepoint)
CLS_MARK = -1     # diacritic/tatweel: deleted in place, does not split
# class > 0: the letter's dense 6-bit code, normalisation applied

MAX_RAW = 32      # raw codepoints examined per word (letters + marks)
CMAX = 20         # normalised letters kept before clitic stripping
MIN_STEM = 3      # letters a clitic strip must leave (tri stems are the
                  # shortest the candidate grid analyses directly)


def classify_cp(cp: int) -> int:
    """Codepoint -> CLS_SEP | CLS_MARK | dense letter code (> 0)."""
    if cp in ab.DIACRITICS or cp == ab.TATWEEL:
        return CLS_MARK
    return ab.CP_TO_CODE.get(ab.NORMALISE.get(cp, cp), CLS_SEP)


def _build_class_lut() -> np.ndarray:
    lut = np.zeros(0x100, np.int32)
    for off in range(0x100):
        lut[off] = classify_cp(0x0600 + off)
    return lut


# int32[256] over the 0x0600 Arabic page; codepoints outside the page are
# separators by construction (classify_codes range-checks before take)
CLASS_LUT = _build_class_lut()

# ---------------------------------------------------------------------------
# clitic patterns (longest first == match priority) and function words
# ---------------------------------------------------------------------------
PROCLITICS = ("وال", "بال", "فال", "كال", "لل", "و", "ف", "ب", "ل", "ك")
ENCLITICS = ("هما", "ها", "هم", "هن", "كم", "كن", "نا", "ني", "ه", "ك")

# Clitic stripping is NOT applied to these (Snippet 1): particles,
# pronouns, demonstratives and common function verbs whose first/last
# letters happen to look like clitics — stripping them manufactures a
# false analysis (كانت -> ك+انت, لكن -> ل+كن, هل -> ه+ل...). Stored
# unnormalised; keys are built through the same classify pipeline.
FUNCTION_WORDS = (
    # prepositions + particles
    "في", "من", "عن", "إلى", "على", "حتى", "منذ", "عند", "لدى", "مع",
    "بين", "فوق", "تحت", "أمام", "خلف", "وراء", "دون", "بعد", "قبل",
    "ضد", "نحو", "عبر", "بل", "قد", "سوف", "لقد", "هل", "لا", "لم",
    "لن", "ما", "إن", "أن", "لو", "لولا", "لعل", "ليت", "كي", "ثم",
    "أو", "أم", "إذ", "إذا", "لما", "لكن", "إنما", "أيضا", "إلا",
    "أما", "كل", "بعض", "غير", "مثل", "أي",
    # pronouns
    "هو", "هي", "هم", "هن", "هما", "أنا", "نحن", "أنت", "أنتم", "أنتن",
    # demonstratives + relatives
    "هذا", "هذه", "ذلك", "تلك", "هؤلاء", "أولئك", "الذي", "التي",
    "الذين",
    # the basmala nouns: ه/هم endings here are part of the word, not
    # object pronouns (الله -> الل under the enclitic rule otherwise)
    "الله", "اللهم",
    # interrogatives
    "ماذا", "لماذا", "متى", "أين", "كيف", "كم",
    # high-frequency function verbs (the Snippet-1 كانت example)
    "كان", "كانت", "كانوا", "يكون", "ليس", "ليست",
)


def _word_codes(word: str) -> tuple[int, ...]:
    return tuple(c for c in (classify_cp(ord(ch)) for ch in word) if c > 0)


PROCLITIC_CODES = tuple(_word_codes(p) for p in PROCLITICS)
ENCLITIC_CODES = tuple(_word_codes(e) for e in ENCLITICS)

FW_MAXLEN = 5                     # packed exemption key covers <= 5 letters
FW_SENTINEL = np.int32(1 << 30)   # > any packed 5-letter key (64^5 - 1)


def pack5(codes) -> int:
    """<= 5 dense codes -> base-64 key < 2^30 (PAD-extended right)."""
    cs = list(codes)[:FW_MAXLEN]
    cs += [0] * (FW_MAXLEN - len(cs))
    k = 0
    for c in cs:
        k = k * 64 + int(c)
    return k


def _build_fw_keys() -> np.ndarray:
    keys = set()
    for w in FUNCTION_WORDS:
        codes = _word_codes(w)
        if not 0 < len(codes) <= FW_MAXLEN:
            raise AssertionError(
                f"function word {w!r} has {len(codes)} letters; the packed"
                f" exemption key covers 1..{FW_MAXLEN}")
        keys.add(pack5(codes))
    return np.asarray(sorted(keys), np.int32)


FW_KEYS = _build_fw_keys()                 # sorted unique, host membership
FW_KEY_SET = frozenset(int(k) for k in FW_KEYS)


def _pad_pow2(keys: np.ndarray, lane: int = 128) -> np.ndarray:
    rp = lane
    while rp < keys.shape[0]:
        rp *= 2
    return np.pad(keys, (0, rp - keys.shape[0]),
                  constant_values=FW_SENTINEL)


# sorted + sentinel-padded to a pow2 >= one lane row: the same layout
# stem_match.pad_dict_sorted gives root dictionaries, so the kernel ships
# it to VMEM as a (rows, 128) tile and bsearch_hit runs unchanged on it
FW_FLAT = _pad_pow2(FW_KEYS)


# ---------------------------------------------------------------------------
# host reference (python strings; the oracle)
# ---------------------------------------------------------------------------
def utf8_len(cp: int) -> int:
    return 1 + (cp >= 0x80) + (cp >= 0x800) + (cp >= 0x10000)


def tokenize_py(text: str) -> list[tuple[tuple[int, ...], int, int]]:
    """text -> [(raw codepoints, byte_start, byte_end)] per word.

    Words are maximal runs of non-separator codepoints; byte offsets are
    utf-8 offsets into ``text.encode()``. Mark-only runs (e.g. a stray
    shadda between spaces) still tokenize — they normalise to an empty
    word row, which the stemmer maps to SRC_NONE.
    """
    toks: list[tuple[tuple[int, ...], int, int]] = []
    cur: list[int] = []
    b = b0 = 0
    for ch in text:
        cp = ord(ch)
        if classify_cp(cp) == CLS_SEP:
            if cur:
                toks.append((tuple(cur), b0, b))
                cur = []
        else:
            if not cur:
                b0 = b
            cur.append(cp)
        b += utf8_len(cp)
    if cur:
        toks.append((tuple(cur), b0, b))
    return toks


def letters_py(cps) -> list[int]:
    """Raw word codepoints -> normalised letter codes (windows applied)."""
    codes: list[int] = []
    for cp in tuple(cps)[:MAX_RAW]:
        c = classify_cp(cp)
        if c > 0:
            codes.append(c)
            if len(codes) == CMAX:
                break
    return codes


def strip_clitics_py(codes) -> tuple[list[int], int, int]:
    """Letter codes -> (stripped codes, proclitic len, enclitic len)."""
    codes = list(codes)
    n = len(codes)
    if n <= FW_MAXLEN and pack5(codes) in FW_KEY_SET:
        return codes, 0, 0
    pro = 0
    for pat in PROCLITIC_CODES:
        ln = len(pat)
        if n - ln >= MIN_STEM and tuple(codes[:ln]) == pat:
            pro = ln
            break
    rem = codes[pro:]
    m = len(rem)
    enc = 0
    for pat in ENCLITIC_CODES:
        ln = len(pat)
        if m - ln >= MIN_STEM and tuple(rem[m - ln:]) == pat:
            enc = ln
            break
    return (rem[:m - enc] if enc else rem), pro, enc


def word_row_py(cps) -> np.ndarray:
    """Raw word codepoints -> the int32[16] stemmer word-tile row."""
    codes, _, _ = strip_clitics_py(letters_py(cps))
    row = codes[:ab.MAXLEN - 1]
    return np.asarray(row + [0] * (ab.MAXLEN - len(row)), np.int32)


def analyze_text_py(text: str) -> tuple[np.ndarray, np.ndarray]:
    """Document -> (words int32[W, 16], spans int32[W, 2] byte offsets)."""
    toks = tokenize_py(text)
    if not toks:
        return (np.zeros((0, ab.MAXLEN), np.int32),
                np.zeros((0, 2), np.int32))
    words = np.stack([word_row_py(cps) for cps, _, _ in toks])
    spans = np.asarray([[b0, b1] for _, b0, b1 in toks], np.int32)
    return words, spans


def coalesce_docs(docs) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Documents -> one codepoint tile with a single 0 separator between
    consecutive docs; returns (chars int32[T], char_offsets int64[D],
    byte_offsets int64[D]) — the offsets of each doc's first codepoint /
    utf-8 byte inside the coalesced tile, so per-tile word positions and
    byte spans map back to per-document ones by subtraction.
    """
    parts: list[np.ndarray] = []
    char_off, byte_off = [], []
    c = b = 0
    for i, d in enumerate(docs):
        if i:
            parts.append(np.zeros(1, np.int32))
            c += 1
            b += 1
        char_off.append(c)
        byte_off.append(b)
        if d:
            parts.append(np.frombuffer(
                d.encode("utf-32-le"), np.uint32).astype(np.int32))
        c += len(d)
        b += len(d.encode("utf-8"))
    chars = (np.concatenate(parts) if parts else np.zeros(0, np.int32))
    return (chars, np.asarray(char_off, np.int64),
            np.asarray(byte_off, np.int64))


# ---------------------------------------------------------------------------
# shared jnp bodies (traced standalone by the reference AND inside the
# Pallas kernel — tables ride in as arguments, never captured constants)
# ---------------------------------------------------------------------------
def classify_codes(chars, lut):
    """int32[...] codepoints -> class, via the CLASS_LUT tile ``lut``
    (int32[256]); anything off the 0x0600 page is a separator."""
    off = chars - 0x0600
    in_page = (off >= 0) & (off < 0x100)
    return jnp.where(in_page,
                     jnp.take(lut, jnp.clip(off, 0, 0xFF), mode="clip"),
                     CLS_SEP)


def strip_and_pack(codes, lens, fw_flat):
    """Normalised letter rows -> stripped, packed word-tile rows.

    codes int32[n, CMAX]  left-aligned letter codes, 0 beyond ``lens``
    lens  int32[n]        letters per row (<= CMAX)
    fw_flat int32[Fp]     FW_FLAT (sorted, sentinel-padded pow2)
    -> int32[n, 16]

    Branchless: function-word exemption via bsearch_hit on the packed
    5-letter key; proclitic as a first-match scan over the pattern list
    (longest first); enclitic chars located by one-hot sums at absolute
    position lens - L + k (no gather along traced offsets); the
    proclitic shift realised as a select over the 4 static shifts.
    """
    from repro.kernels import stem_match as sm  # lazy: core -> kernels

    codes = codes.astype(jnp.int32)
    lens = lens.astype(jnp.int32)
    n, cm = codes.shape
    key5 = ((((codes[:, 0] * 64 + codes[:, 1]) * 64 + codes[:, 2]) * 64
             + codes[:, 3]) * 64 + codes[:, 4])
    exempt = (lens <= FW_MAXLEN) & sm.bsearch_hit(fw_flat, key5)

    pro = jnp.zeros((n,), jnp.int32)
    found = exempt
    for pat in PROCLITIC_CODES:
        ln = len(pat)
        m = lens - ln >= MIN_STEM
        for k, c in enumerate(pat):
            m &= codes[:, k] == c
        pro = jnp.where(m & ~found, ln, pro)
        found |= m

    rem_len = lens - pro
    j = jnp.arange(cm, dtype=jnp.int32)[None, :]

    def char_at(pos):   # codes[i, pos[i]] without a gather (one-hot sum)
        return jnp.sum(jnp.where(j == pos[:, None], codes, 0), axis=1)

    enc = jnp.zeros((n,), jnp.int32)
    found = exempt
    for pat in ENCLITIC_CODES:
        ln = len(pat)
        m = rem_len - ln >= MIN_STEM
        for k, c in enumerate(pat):
            # the enclitic's chars sit at absolute column lens - ln + k
            # regardless of the proclitic cut (both count from the left)
            m &= char_at(lens - ln + k) == c
        enc = jnp.where(m & ~found, ln, enc)
        found |= m

    out_len = jnp.minimum(rem_len - enc, ab.MAXLEN - 1)
    # shift left by pro (0..3): select over the static shifts; cm >= 19
    # guarantees every [p, p + 16) window exists
    shifted = jnp.zeros((n, ab.MAXLEN), jnp.int32)
    for p in sorted({len(pat) for pat in PROCLITIC_CODES} | {0}):
        shifted = jnp.where((pro == p)[:, None],
                            codes[:, p:p + ab.MAXLEN], shifted)
    keep = jnp.arange(ab.MAXLEN, dtype=jnp.int32)[None, :] < out_len[:, None]
    return jnp.where(keep, shifted, 0)


# ---------------------------------------------------------------------------
# jnp geometry pre-pass + scatter-based reference
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TextGeometry:
    """Per-word layout of a codepoint tile (all jnp, shapes static).

    starts  int32[Wp]    char index of each word's first codepoint
    lens    int32[Wp]    raw codepoint count (un-windowed; 0 past n_words)
    spans   int32[Wp,2]  utf-8 byte [start, end) into the tile's encoding
    n_words int32        actual word count (rows past it are zero)
    """

    starts: object
    lens: object
    spans: object
    n_words: object


def _word_capacity(t: int, block_w: int, max_words) -> int:
    w = (t // 2 + 1) if max_words is None else max_words
    return -(-w // block_w) * block_w


def segment_geometry(chars, *, block_w: int = 128,
                     max_words: int | None = None) -> TextGeometry:
    """Codepoint tile -> word starts/lengths/byte spans (scatter-based).

    The capacity default T // 2 + 1 is exact (words alternate with at
    least one separator), so no word is ever dropped unless the caller
    caps ``max_words`` below the true count.
    """
    chars = jnp.asarray(chars, jnp.int32)
    t = chars.shape[0]
    if t == 0:
        raise ValueError("segment_geometry needs a non-empty codepoint"
                         " tile; pad with the 0 separator")
    wp = _word_capacity(t, block_w, max_words)
    cls = classify_codes(chars, jnp.asarray(CLASS_LUT))
    is_word = cls != CLS_SEP
    prev = jnp.concatenate([jnp.zeros(1, bool), is_word[:-1]])
    nxt = jnp.concatenate([is_word[1:], jnp.zeros(1, bool)])
    wstart = is_word & ~prev
    wend = is_word & ~nxt
    wid = jnp.cumsum(wstart.astype(jnp.int32)) - 1
    n_words = jnp.sum(wstart.astype(jnp.int32))
    idx = jnp.arange(t, dtype=jnp.int32)
    drop = jnp.int32(wp)                       # OOB row -> mode="drop"
    sidx = jnp.where(wstart, wid, drop)
    eidx = jnp.where(wend, wid, drop)
    starts = jnp.zeros(wp, jnp.int32).at[sidx].set(idx, mode="drop")
    ends = jnp.zeros(wp, jnp.int32).at[eidx].set(idx, mode="drop")
    blen = (1 + (chars >= 0x80).astype(jnp.int32)
            + (chars >= 0x800).astype(jnp.int32)
            + (chars >= 0x10000).astype(jnp.int32))
    boff = jnp.cumsum(blen) - blen             # bytes before each char
    b0 = jnp.zeros(wp, jnp.int32).at[sidx].set(boff, mode="drop")
    b1 = jnp.zeros(wp, jnp.int32).at[eidx].set(boff + blen, mode="drop")
    valid = jnp.arange(wp) < n_words
    lens = jnp.where(valid, ends - starts + 1, 0)
    spans = jnp.where(valid[:, None], jnp.stack([b0, b1], axis=-1), 0)
    return TextGeometry(starts=jnp.where(valid, starts, 0), lens=lens,
                        spans=spans, n_words=n_words)


def frontend_reference(chars, *, block_w: int = 128,
                       max_words: int | None = None):
    """Pure-jnp front end: codepoint tile -> (words int32[Wp, 16],
    TextGeometry). Bit-identical to the host reference row-by-row and to
    kernels.text_frontend.text_frontend_pallas (which shares
    strip_and_pack but gathers per word instead of scattering per char).
    """
    chars = jnp.asarray(chars, jnp.int32)
    t = chars.shape[0]
    geo = segment_geometry(chars, block_w=block_w, max_words=max_words)
    wp = geo.starts.shape[0]
    lut = jnp.asarray(CLASS_LUT)
    cls = classify_codes(chars, lut)
    is_word = cls != CLS_SEP
    is_letter = cls > 0
    prev = jnp.concatenate([jnp.zeros(1, bool), is_word[:-1]])
    wid = jnp.cumsum((is_word & ~prev).astype(jnp.int32)) - 1
    start_of = jnp.take(geo.starts, jnp.clip(wid, 0, wp - 1), mode="clip")
    raw_off = jnp.arange(t, dtype=jnp.int32) - start_of
    g_excl = jnp.cumsum(is_letter.astype(jnp.int32)) - is_letter
    pos = g_excl - jnp.take(g_excl, start_of, mode="clip")
    cond = is_letter & (raw_off < MAX_RAW) & (pos < CMAX) & (wid < wp)
    rows = jnp.where(cond, wid, wp)            # OOB -> dropped
    grid = jnp.zeros((wp, CMAX), jnp.int32).at[rows, pos].set(
        cls, mode="drop")
    nlet = jnp.zeros(wp, jnp.int32).at[rows].add(1, mode="drop")
    words = strip_and_pack(grid, nlet, jnp.asarray(FW_FLAT))
    return words, geo
