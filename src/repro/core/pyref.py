"""Pure-Python oracle of the paper's verb-root-extraction algorithm.

This is the executable specification: every JAX / Pallas implementation in
the repo is tested against this module. It follows the paper's flowcharts
(Figs 1-4), the VHDL substring-truncation semantics (Fig 12 / Table 3) and
the infix-processing passes (Figs 18-19).

Candidate geometry: a stem is ``word[p+1 : s]`` for a prefix cut ``p`` (−1
== no prefix) and suffix start ``s`` (``n`` == no suffix). Only lengths
3 (trilateral) and 4 (quadrilateral) are kept, so for each ``p`` the pair
is fully determined by the length: ``s = p + 1 + L``. The VHDL's 6-slot
candidate arrays therefore exactly hold the 6 possible prefix cuts -- the
``count1 < 5`` cap never drops a candidate (see DESIGN.md).

Produce-Prefixes masking: cumulative AND of prefix-letter membership from
the word start (mirroring the documented Produce-Suffixes rule, anchored at
the word end), with one linguistic refinement required by the paper's own
worked example (سيلعبون → prefixes mask 1100000): the person-marker ي is
always the *final* prefix letter, so the run terminates immediately after
the first ي. This is consistent with both worked examples in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import alphabet as ab

PREFIX_SET = frozenset(int(c) for c in ab.PREFIX_CODES)
SUFFIX_SET = frozenset(int(c) for c in ab.SUFFIX_CODES)
INFIX_SET = frozenset(int(c) for c in ab.INFIX_CODES)

# Root-source tags (shared with the JAX implementation).
SRC_NONE = 0          # no root found
SRC_TRI = 1           # direct trilateral match
SRC_QUAD = 2          # direct quadrilateral match
SRC_RESTORED = 3      # Restore-Original-Form (hollow verb, ا→و)
SRC_DEINFIX_TRI = 4   # Remove-Infix on a quadrilateral stem → trilateral
SRC_DEINFIX_BI = 5    # Remove-Infix on a trilateral stem → bilateral
# extended rule pool (beyond-paper; the paper's §7 future work)
SRC_EXT_DEFECTIVE = 6  # final ى → ي (defective verbs: سقى → سقي)
SRC_EXT_HOLLOW_Y = 7   # hollow ا → ي (باع → بيع)

ALEF_MAQSURA = 30  # dense code of ى (see alphabet.CP_TO_CODE ordering)


@dataclass
class RootDict:
    """Stored root lists (dense-code tuples)."""

    tri: frozenset = field(default_factory=frozenset)    # {(c0,c1,c2)}
    quad: frozenset = field(default_factory=frozenset)   # {(c0,c1,c2,c3)}
    bi: frozenset = field(default_factory=frozenset)     # {(c0,c1)}

    @staticmethod
    def from_words(tri=(), quad=(), bi=()):
        enc = lambda w: tuple(int(c) for c in ab.encode_word(w) if c)
        return RootDict(
            tri=frozenset(enc(w) for w in tri),
            quad=frozenset(enc(w) for w in quad),
            bi=frozenset(enc(w) for w in bi),
        )


def check_and_produce(word: list[int]):
    """Stages 1-2: affix checks + contiguous-run masking.

    Returns (pp, ps): pp[i] true iff chars 0..i form a valid prefix run
    (i < 5); ps[j] true iff chars j..n-1 are all suffix letters.
    """
    n = len(word)
    pp = []
    run = True
    seen_yeh = False
    for i in range(min(5, n)):
        if seen_yeh:
            run = False
        run = run and word[i] in PREFIX_SET
        pp.append(run)
        if word[i] == ab.YEH:
            seen_yeh = True
    ps = [False] * n
    run = True
    for j in range(n - 1, -1, -1):
        run = run and word[j] in SUFFIX_SET
        ps[j] = run
    return pp, ps


def generate_stems(word: list[int]):
    """Stages 3-4: substring truncation + size filter (VHDL Fig 12 order).

    Returns (tri, quad): lists of stems in prefix-cut-ascending order, with
    validity implied by inclusion.
    """
    n = len(word)
    pp, ps = check_and_produce(word)

    def p_valid(p):
        return p == -1 or (p < len(pp) and pp[p])

    def s_valid(s):
        return s == n or (0 <= s < n and ps[s])

    tri, quad = [], []
    for p in range(-1, 5):
        if not p_valid(p):
            continue
        for L, out in ((3, tri), (4, quad)):
            s = p + 1 + L
            if s <= n and s_valid(s):
                out.append(tuple(word[p + 1 : s]))
    return tri, quad


def extract_root(word_codes, roots: RootDict, infix: bool = True,
                 extended: bool = False):
    """Full stage-5 compare + infix recovery. Returns (root_tuple, source).

    Priority: direct tri > direct quad > restored tri (ا→و) >
    remove-infix quad→tri > remove-infix tri→bi
    [> extended: final ى→ي > hollow ا→ي].

    extended=True enables the beyond-paper rule pool (the paper's §7
    future work: "widening the pool of implemented rules").
    """
    word = [int(c) for c in word_codes if int(c) != 0]
    tri, quad = generate_stems(word)

    for st in tri:
        if st in roots.tri:
            return st, SRC_TRI
    for st in quad:
        if st in roots.quad:
            return st, SRC_QUAD
    if infix:
        # Restore Original Form (Fig 19): 2nd char ا → و on trilaterals.
        for st in tri:
            if st[1] == ab.ALEF:
                cand = (st[0], ab.WAW, st[2])
                if cand in roots.tri:
                    return cand, SRC_RESTORED
        # Remove Infix (Fig 18): drop infix 2nd char.
        for st in quad:
            if st[1] in INFIX_SET:
                cand = (st[0], st[2], st[3])
                if cand in roots.tri:
                    return cand, SRC_DEINFIX_TRI
        for st in tri:
            if st[1] in INFIX_SET:
                cand = (st[0], st[2])
                if cand in roots.bi:
                    return cand, SRC_DEINFIX_BI
    if extended:
        for st in tri:
            if st[2] == ALEF_MAQSURA:  # defective: سقى → سقي
                cand = (st[0], st[1], ab.YEH)
                if cand in roots.tri:
                    return cand, SRC_EXT_DEFECTIVE
        for st in tri:
            if st[1] == ab.ALEF:       # hollow-ي: باع → بيع
                cand = (st[0], ab.YEH, st[2])
                if cand in roots.tri:
                    return cand, SRC_EXT_HOLLOW_Y
    return (), SRC_NONE


def stem_word(text: str, roots: RootDict, infix: bool = True,
              extended: bool = False) -> tuple[str, int]:
    """Convenience: string in, (root string, source tag) out."""
    codes = ab.encode_word(text)
    root, src = extract_root(codes, roots, infix=infix, extended=extended)
    return ab.decode_word(root), src
