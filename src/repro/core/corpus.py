"""Root dictionary + synthetic corpus with Zipf frequency skew.

The dictionary mixes ~140 real high-frequency Arabic roots (including every
root of the paper's Table 7) with deterministic pseudo-roots to reach a
realistic dictionary size (the Quran yields 1,767 distinct roots; general
dictionaries hold 5-10k). Pseudo-roots make the Compare stage realistically
selective — more entries mean more accidental matches on wrong truncations,
exactly the accuracy/coverage trade-off LB stemmers face.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import alphabet as ab
from repro.core import conjugator, pyref

# The paper's Table 7 roots first.
TABLE7_ROOTS = ["علم", "كفر", "قول", "نفس", "نزل", "عمل", "خلق", "جعل", "كذب", "كون"]

REAL_TRI_ROOTS = TABLE7_ROOTS + [
    "كتب", "درس", "لعب", "سقي", "قرا", "فتح", "نصر", "ضرب", "سمع", "بصر",
    "قلب", "رحم", "غفر", "صبر", "شكر", "ذكر", "دخل", "خرج", "رجع", "وصل",
    "قطع", "جمع", "فرق", "حمل", "رفع", "وضع", "منع", "دفع", "قتل", "ولد",
    "كبر", "صغر", "طلب", "وجد", "فقد", "اكل", "شرب", "قوم", "جلس", "مشي",
    "جري", "سبح", "زرع", "حصد", "بيع", "ملك", "حكم", "عدل", "ظلم", "صدق",
    "حسب", "عدد", "قسم", "ضعف", "سعد", "حزن", "فرح", "غضب", "خوف", "رجو",
    "دعو", "سجد", "ركع", "طهر", "حرم", "وجب", "سقط", "نهض", "بني", "هدم",
    "سكن", "رحل", "سفر", "عبر", "غرق", "هلك", "سلم", "نظر", "سال", "جوب",
    "حضر", "غيب", "قرب", "بعد", "وقف", "سير", "طير", "نوم", "صحو", "موت",
    "حيي", "زاد", "نقص", "بدا", "ختم", "وعد", "نكث", "شهد", "غزو", "صون",
    "ذهب", "جاء", "عرف", "جهل", "فهم", "حفظ", "نسي", "صنع", "كسب", "خسر",
    "ربح", "تجر", "زور", "صار", "ظهر", "بطن", "علن", "خفي", "كشف", "ستر",
]

REAL_QUAD_ROOTS = [
    "دحرج", "زلزل", "ترجم", "بعثر", "طمان", "وسوس", "زخرف", "سيطر",
    "هيمن", "عسكر", "قهقه", "غرغر", "ثرثر", "برهن", "سلسل", "زحزح",
]

REAL_BI_ROOTS = [
    "مد", "شد", "ظن", "عد", "حب", "حج", "حس", "حق", "حل", "دق",
    "دل", "رد", "سب", "سد", "شق", "صب", "صد", "ضل", "ضم", "عض",
    "غش", "فر", "قص", "كف", "لف", "لم", "مس", "من", "هز", "ود",
]

# Letters used for pseudo-root sampling: strong consonants only, so random
# roots neither collide with affix machinery nor look degenerate.
_STRONG = list("بجدحخذرزسشصضطظعغفقكلمهث")


def _pseudo_roots(n: int, length: int, seed: int, taken: set) -> list[str]:
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        letters = rng.choice(len(_STRONG), size=length)
        if len(set(letters.tolist())) < length:  # no geminates in pseudo roots
            continue
        r = "".join(_STRONG[i] for i in letters)
        if r in taken:
            continue
        taken.add(r)
        out.append(r)
    return out


def build_dictionary(n_tri: int = 2000, n_quad: int = 200, seed: int = 0) -> pyref.RootDict:
    taken = set(REAL_TRI_ROOTS) | set(REAL_QUAD_ROOTS)
    tri = REAL_TRI_ROOTS + _pseudo_roots(max(0, n_tri - len(REAL_TRI_ROOTS)), 3, seed, taken)
    quad = REAL_QUAD_ROOTS + _pseudo_roots(max(0, n_quad - len(REAL_QUAD_ROOTS)), 4, seed + 1, taken)
    return pyref.RootDict.from_words(tri=tri, quad=quad, bi=REAL_BI_ROOTS)


def _synthetic_keys(n: int, arity: int, seed: int, taken: set) -> np.ndarray:
    """n unique packed int32 keys shaped like real `arity`-letter roots
    (dense codes in 1..N_CODES-1, trailing chars zero), disjoint from
    ``taken``. Vectorised rejection sampling."""
    rng = np.random.default_rng(seed)
    out: list[int] = []
    seen = set(taken)
    while len(out) < n:
        c = rng.integers(1, ab.N_CODES, size=(2 * (n - len(out)) + 64, 4),
                         dtype=np.int64)
        c[:, arity:] = 0
        keys = ((c[:, 0] * 64 + c[:, 1]) * 64 + c[:, 2]) * 64 + c[:, 3]
        for k in keys.tolist():
            if k not in seen:
                seen.add(k)
                out.append(k)
                if len(out) == n:
                    break
    return np.asarray(out, np.int32)


def grow_root_arrays(arrays, n_keys: int, seed: int = 0):
    """Grow packed RootDictArrays to ~``n_keys`` total keys with synthetic
    roots (real keys kept, so real matches still occur).

    Production lexicons run to hundreds of thousands of entries — far past
    what ``build_dictionary``'s linguistic generator can produce (distinct
    strong-consonant trilaterals top out near 33^3). The streamed-megakernel
    scaling benchmark and the >64K-key parity tests need dictionaries at
    that scale, so the bulk lands in the quadrilateral table (33^4 ≈ 1.19M
    capacity) with tri/bi capped well under their key-space saturation.
    Returns a new RootDictArrays with sorted unique int32 keys per table.
    """
    from repro.core import stemmer  # lazy: stemmer imports corpus's peers

    base = {
        "tri": np.asarray(arrays.tri),
        "quad": np.asarray(arrays.quad),
        "bi": np.asarray(arrays.bi),
    }
    n_base = sum(v.size for v in base.values())
    extra = max(0, n_keys - n_base)
    want = {
        "tri": min(extra // 2, 16_000),
        "bi": min(extra // 64, 500),
    }
    want["quad"] = extra - want["tri"] - want["bi"]
    taken = set(np.concatenate(list(base.values())).tolist())
    grown = {}
    for arity, name in ((3, "tri"), (4, "quad"), (2, "bi")):
        synth = _synthetic_keys(want[name], arity, seed + arity, taken)
        taken.update(synth.tolist())
        merged = np.unique(np.concatenate([base[name], synth])).astype(np.int32)
        grown[name] = np.asarray(merged)
    import jax.numpy as jnp

    return stemmer.RootDictArrays(tri=jnp.asarray(grown["tri"]),
                                  quad=jnp.asarray(grown["quad"]),
                                  bi=jnp.asarray(grown["bi"]))


def build_corpus(
    n_words: int = 20000, seed: int = 0, zipf_a: float = 1.3, rich: bool = True
) -> tuple[list[str], list[str], list[str]]:
    """-> (words, truth_roots, tags); root frequencies follow a Zipf law,
    mirroring the extreme skew of the Quran text (قول appears 1,722 times).
    """
    rng = np.random.default_rng(seed)
    roots = REAL_TRI_ROOTS + REAL_QUAD_ROOTS
    # Zipf-ranked sampling over the real-root list.
    ranks = np.arange(1, len(roots) + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    form_cache: dict[str, list[tuple[str, str]]] = {}
    words, truths, tags = [], [], []
    for ridx in rng.choice(len(roots), size=n_words, p=probs):
        root = roots[int(ridx)]
        if root not in form_cache:
            form_cache[root] = conjugator.conjugate(root, rich=rich)
        forms = form_cache[root]
        w, t = forms[int(rng.integers(len(forms)))]
        words.append(w)
        truths.append(root)
        tags.append(t)
    return words, truths, tags


def encode_corpus(words: list[str]) -> np.ndarray:
    return ab.encode_batch(words)


# ---------------------------------------------------------------------------
# Corpus-scale document streams (the batch-indexing workload, DESIGN.md §8)
# ---------------------------------------------------------------------------
# build_corpus() materialises python string lists — fine at 20K words,
# hopeless at 10M. The streaming generators below sample from a prebuilt
# TokenTable instead: every distinct surface token's text AND its
# kernel-front-end word row (textnorm.word_row_py — the PR 7 rule
# pipeline: normalise, clitic strip, pack) are computed exactly once, so
# emitting a chunk is one vectorised rng.choice + one numpy gather. A
# generated document therefore round-trips the text front end by
# construction: analyze_text_py(" ".join(texts)) produces precisely the
# table rows the word stream hands the megakernel directly.


@dataclass(frozen=True)
class TokenTable:
    """Distinct surface tokens with precomputed front-end word rows.

    texts  tuple[str]            surface forms (clitics attached)
    rows   int32[n_tokens, 16]   textnorm.word_row_py of each token
    probs  float64[n_tokens]     sampling distribution (Zipf over roots,
                                 uniform over a root's tokens)
    """

    texts: tuple
    rows: np.ndarray
    probs: np.ndarray

    @property
    def n_tokens(self) -> int:
        return len(self.texts)


def build_token_table(*, forms_per_root: int = 24, clitic_every: int = 3,
                      zipf_a: float = 1.3, rich: bool = True) -> TokenTable:
    """Enumerate the corpus streams' token universe, deterministically.

    Every real root contributes its first ``forms_per_root`` conjugated
    forms; every ``clitic_every``-th form additionally appears with a
    textnorm proclitic/enclitic attached (cycled, not sampled — the
    table itself is rng-free). Root probabilities follow the same Zipf
    law as build_corpus; a root's mass splits uniformly over its tokens.
    """
    from repro.core import textnorm as tn  # lazy: textnorm imports peers

    roots = REAL_TRI_ROOTS + REAL_QUAD_ROOTS
    ranks = np.arange(1, len(roots) + 1, dtype=np.float64)
    root_p = ranks ** (-zipf_a)
    root_p /= root_p.sum()

    texts, probs = [], []
    pro = tn.PROCLITICS
    enc = tn.ENCLITICS
    for ridx, root in enumerate(roots):
        forms = [w for w, _ in conjugator.conjugate(root, rich=rich)]
        forms = list(dict.fromkeys(forms))[:forms_per_root]
        toks = list(forms)
        for i, w in enumerate(forms):
            if clitic_every and i % clitic_every == 0:
                toks.append(pro[(ridx + i) % len(pro)] + w)
            if clitic_every and i % clitic_every == 1:
                toks.append(w + enc[(ridx + i) % len(enc)])
        toks = list(dict.fromkeys(toks))
        texts.extend(toks)
        probs.extend([root_p[ridx] / len(toks)] * len(toks))
    rows = np.stack([tn.word_row_py(tuple(map(ord, t))) for t in texts])
    probs = np.asarray(probs, np.float64)
    return TokenTable(texts=tuple(texts), rows=rows, probs=probs / probs.sum())


@dataclass(frozen=True)
class CorpusChunk:
    """One streamed slice of a synthetic corpus, pre-encoded.

    words      int32[n, 16]  front-end word rows (megakernel input)
    doc_ids    int64[n]      global document id per word
    positions  int32[n]      word position within its document
    start_word int           global index of words[0] in the corpus
    """

    words: np.ndarray
    doc_ids: np.ndarray
    positions: np.ndarray
    start_word: int

    @property
    def n_words(self) -> int:
        return self.words.shape[0]


def stream_corpus_words(n_words: int, *, seed: int = 0,
                        chunk_words: int = 65536, words_per_doc: int = 1000,
                        table: TokenTable | None = None):
    """Yield a seeded ``n_words``-word corpus as CorpusChunks of encoded
    word rows — the fast ingest path for corpus-scale index builds.

    Deterministic per (seed, chunk_words, words_per_doc): chunk ``c`` is
    drawn from ``default_rng([seed, c])``, so resuming a checkpointed
    build re-yields byte-identical chunks without replaying the earlier
    ones' rng streams. Documents are ``words_per_doc`` words long and
    split across chunk boundaries exactly (doc ids and positions are
    functions of the global word index alone).
    """
    if table is None:
        table = build_token_table()
    for c, w0 in enumerate(range(0, n_words, chunk_words)):
        n = min(chunk_words, n_words - w0)
        rng = np.random.default_rng([seed, c])
        tok = rng.choice(table.n_tokens, size=n, p=table.probs)
        gwi = w0 + np.arange(n, dtype=np.int64)
        yield CorpusChunk(words=table.rows[tok],
                          doc_ids=gwi // words_per_doc,
                          positions=(gwi % words_per_doc).astype(np.int32),
                          start_word=w0)


def stream_corpus_docs(n_words: int, *, seed: int = 0,
                       chunk_words: int = 65536, words_per_doc: int = 100,
                       table: TokenTable | None = None):
    """The same corpus as :func:`stream_corpus_words` (same seed → the
    same token sequence) but rendered as raw text: yields
    ``(doc0, docs)`` per chunk where ``docs`` is the chunk's list of
    document strings and ``doc0`` the global id of ``docs[0]``.

    ``chunk_words`` must be a multiple of ``words_per_doc`` so documents
    never straddle a text chunk (the byte-ingest path attributes words
    to documents per chunk). Each document round-trips the kernel front
    end to exactly the word rows the words stream emits.
    """
    if chunk_words % words_per_doc:
        raise ValueError(
            f"chunk_words ({chunk_words}) must be a multiple of"
            f" words_per_doc ({words_per_doc}) for the document stream")
    if table is None:
        table = build_token_table()
    for c, w0 in enumerate(range(0, n_words, chunk_words)):
        n = min(chunk_words, n_words - w0)
        rng = np.random.default_rng([seed, c])
        tok = rng.choice(table.n_tokens, size=n, p=table.probs)
        docs = [" ".join(table.texts[t] for t in tok[d0:d0 + words_per_doc])
                for d0 in range(0, n, words_per_doc)]
        yield w0 // words_per_doc, docs
