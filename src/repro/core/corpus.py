"""Root dictionary + synthetic corpus with Zipf frequency skew.

The dictionary mixes ~140 real high-frequency Arabic roots (including every
root of the paper's Table 7) with deterministic pseudo-roots to reach a
realistic dictionary size (the Quran yields 1,767 distinct roots; general
dictionaries hold 5-10k). Pseudo-roots make the Compare stage realistically
selective — more entries mean more accidental matches on wrong truncations,
exactly the accuracy/coverage trade-off LB stemmers face.
"""
from __future__ import annotations

import numpy as np

from repro.core import alphabet as ab
from repro.core import conjugator, pyref

# The paper's Table 7 roots first.
TABLE7_ROOTS = ["علم", "كفر", "قول", "نفس", "نزل", "عمل", "خلق", "جعل", "كذب", "كون"]

REAL_TRI_ROOTS = TABLE7_ROOTS + [
    "كتب", "درس", "لعب", "سقي", "قرا", "فتح", "نصر", "ضرب", "سمع", "بصر",
    "قلب", "رحم", "غفر", "صبر", "شكر", "ذكر", "دخل", "خرج", "رجع", "وصل",
    "قطع", "جمع", "فرق", "حمل", "رفع", "وضع", "منع", "دفع", "قتل", "ولد",
    "كبر", "صغر", "طلب", "وجد", "فقد", "اكل", "شرب", "قوم", "جلس", "مشي",
    "جري", "سبح", "زرع", "حصد", "بيع", "ملك", "حكم", "عدل", "ظلم", "صدق",
    "حسب", "عدد", "قسم", "ضعف", "سعد", "حزن", "فرح", "غضب", "خوف", "رجو",
    "دعو", "سجد", "ركع", "طهر", "حرم", "وجب", "سقط", "نهض", "بني", "هدم",
    "سكن", "رحل", "سفر", "عبر", "غرق", "هلك", "سلم", "نظر", "سال", "جوب",
    "حضر", "غيب", "قرب", "بعد", "وقف", "سير", "طير", "نوم", "صحو", "موت",
    "حيي", "زاد", "نقص", "بدا", "ختم", "وعد", "نكث", "شهد", "غزو", "صون",
    "ذهب", "جاء", "عرف", "جهل", "فهم", "حفظ", "نسي", "صنع", "كسب", "خسر",
    "ربح", "تجر", "زور", "صار", "ظهر", "بطن", "علن", "خفي", "كشف", "ستر",
]

REAL_QUAD_ROOTS = [
    "دحرج", "زلزل", "ترجم", "بعثر", "طمان", "وسوس", "زخرف", "سيطر",
    "هيمن", "عسكر", "قهقه", "غرغر", "ثرثر", "برهن", "سلسل", "زحزح",
]

REAL_BI_ROOTS = [
    "مد", "شد", "ظن", "عد", "حب", "حج", "حس", "حق", "حل", "دق",
    "دل", "رد", "سب", "سد", "شق", "صب", "صد", "ضل", "ضم", "عض",
    "غش", "فر", "قص", "كف", "لف", "لم", "مس", "من", "هز", "ود",
]

# Letters used for pseudo-root sampling: strong consonants only, so random
# roots neither collide with affix machinery nor look degenerate.
_STRONG = list("بجدحخذرزسشصضطظعغفقكلمهث")


def _pseudo_roots(n: int, length: int, seed: int, taken: set) -> list[str]:
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        letters = rng.choice(len(_STRONG), size=length)
        if len(set(letters.tolist())) < length:  # no geminates in pseudo roots
            continue
        r = "".join(_STRONG[i] for i in letters)
        if r in taken:
            continue
        taken.add(r)
        out.append(r)
    return out


def build_dictionary(n_tri: int = 2000, n_quad: int = 200, seed: int = 0) -> pyref.RootDict:
    taken = set(REAL_TRI_ROOTS) | set(REAL_QUAD_ROOTS)
    tri = REAL_TRI_ROOTS + _pseudo_roots(max(0, n_tri - len(REAL_TRI_ROOTS)), 3, seed, taken)
    quad = REAL_QUAD_ROOTS + _pseudo_roots(max(0, n_quad - len(REAL_QUAD_ROOTS)), 4, seed + 1, taken)
    return pyref.RootDict.from_words(tri=tri, quad=quad, bi=REAL_BI_ROOTS)


def build_corpus(
    n_words: int = 20000, seed: int = 0, zipf_a: float = 1.3, rich: bool = True
) -> tuple[list[str], list[str], list[str]]:
    """-> (words, truth_roots, tags); root frequencies follow a Zipf law,
    mirroring the extreme skew of the Quran text (قول appears 1,722 times).
    """
    rng = np.random.default_rng(seed)
    roots = REAL_TRI_ROOTS + REAL_QUAD_ROOTS
    # Zipf-ranked sampling over the real-root list.
    ranks = np.arange(1, len(roots) + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    form_cache: dict[str, list[tuple[str, str]]] = {}
    words, truths, tags = [], [], []
    for ridx in rng.choice(len(roots), size=n_words, p=probs):
        root = roots[int(ridx)]
        if root not in form_cache:
            form_cache[root] = conjugator.conjugate(root, rich=rich)
        forms = form_cache[root]
        w, t = forms[int(rng.integers(len(forms)))]
        words.append(w)
        truths.append(root)
        tags.append(t)
    return words, truths, tags


def encode_corpus(words: list[str]) -> np.ndarray:
    return ab.encode_batch(words)
