"""Arabic alphabet tables, normalisation and fixed-width encoding.

The paper (§3.1, §5.2) processes 16-bit Arabic Unicode with:
  - diacritics stripped,
  - the technical difference between ا and أ ignored,
  - a fixed 15-character input register file sized for the longest Arabic
    word (أفاستسقيناكموها).

We keep the paper's conventions but use a 16-slot tensor (15 chars + 1 pad
slot) so shapes stay lane-friendly, and additionally define a dense 6-bit
per-letter code so a 4-letter stem packs into a single int32 key (<2^24),
which is what the compare-stage kernels and the sorted-search variant use.
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Codepoints
# ---------------------------------------------------------------------------
# Base Arabic letters (after normalisation). 36 entries < 64 -> 6-bit codes.
_LETTERS = [
    0x0621,  # ء hamza
    0x0627,  # ا alef (normalisation target for أ إ آ ٱ)
    0x0628,  # ب
    0x0629,  # ة teh marbuta
    0x062A,  # ت
    0x062B,  # ث
    0x062C,  # ج
    0x062D,  # ح
    0x062E,  # خ
    0x062F,  # د
    0x0630,  # ذ
    0x0631,  # ر
    0x0632,  # ز
    0x0633,  # س
    0x0634,  # ش
    0x0635,  # ص
    0x0636,  # ض
    0x0637,  # ط
    0x0638,  # ظ
    0x0639,  # ع
    0x063A,  # غ
    0x0641,  # ف
    0x0642,  # ق
    0x0643,  # ك
    0x0644,  # ل
    0x0645,  # م
    0x0646,  # ن
    0x0647,  # ه
    0x0648,  # و
    0x0649,  # ى alef maqsura
    0x064A,  # ي
    0x0624,  # ؤ waw-hamza
    0x0626,  # ئ yeh-hamza
]

PAD = 0  # empty register slot ("U" in the paper's ModelSim traces)

# Normalisation map: hamza-carrier alef forms collapse onto plain alef (the
# paper explicitly ignores the ا/أ distinction) and taa marbuta onto teh —
# the full Snippet-1 rule set. ة only ever occurs word-finally in correct
# orthography and reads as ت there, so the collapse is unconditional; ت is a
# SUFFIX letter, so the stemmer's prefix/suffix cuts still reach the root.
TATWEEL = 0x0640     # ـ kashida: elongation filler, stripped like a mark
NORMALISE = {
    0x0622: 0x0627,  # آ
    0x0623: 0x0627,  # أ
    0x0625: 0x0627,  # إ
    0x0671: 0x0627,  # ٱ wasla
    0x0629: 0x062A,  # ة -> ت taa marbuta
}

# Diacritics stripped from input (§3.1): fatha, damma, kasra, sukun, shadda,
# tanween forms, the hamza/madda combining marks, superscript alef, the rest
# of the 0x0656-0x065F combining block, and the Quranic annotation marks
# (small high/low signs, sajdah, stop marks — U+06D6..U+06ED) that Quranic
# text carries alongside ordinary tashkil.
DIACRITICS = (set(range(0x064B, 0x0660))            # tashkil + 0653-065F
              | {0x0670}                            # superscript alef
              | set(range(0x06D6, 0x06DD))          # small high ligatures
              | set(range(0x06DF, 0x06E5))          # small high/low signs
              | {0x06E7, 0x06E8}                    # small high yeh/noon
              | set(range(0x06EA, 0x06EE)))         # empty centre marks
# back-compat aliases (pre-PR 7 private names)
_NORMALISE = NORMALISE
_DIACRITICS = DIACRITICS

MAXLEN = 16          # 15-char register file + 1 pad slot (paper uses 15)
WORD_SLOTS = MAXLEN

# Affix letter groups (paper §1.1):
#   prefixes: the 7 letters of فسألتني  (hamza normalised to alef)
#   suffixes: the 9 letters of التهكمون (+ي, see DESIGN.md deviation note)
#   infixes : the 5 letters ا ت و ن ي
PREFIX_LETTERS = [0x0627, 0x062A, 0x0633, 0x0641, 0x0644, 0x0646, 0x064A]
SUFFIX_LETTERS = [0x0627, 0x0644, 0x062A, 0x0647, 0x0643, 0x0645, 0x0648,
                  0x0646, 0x064A]
INFIX_LETTERS = [0x0627, 0x062A, 0x0648, 0x0646, 0x064A]

# 6-bit dense code: 0 reserved for PAD, letters from 1.
CP_TO_CODE = {PAD: 0}
CODE_TO_CP = {0: PAD}
for _i, _cp in enumerate(_LETTERS, start=1):
    CP_TO_CODE[_cp] = _i
    CODE_TO_CP[_i] = _cp
N_CODES = len(_LETTERS) + 1          # 34
CODE_BITS = 6                        # 4 codes pack into 24 bits < int32

# LUT from (codepoint - 0x0600) -> dense code, for vectorised compression.
_LUT = np.zeros(0x100, dtype=np.int32)
for _cp, _c in CP_TO_CODE.items():
    if _cp:
        _LUT[_cp - 0x0600] = _c
CODE_LUT = _LUT  # int32[256]

PREFIX_CODES = np.array([CP_TO_CODE[c] for c in PREFIX_LETTERS], np.int32)
SUFFIX_CODES = np.array([CP_TO_CODE[c] for c in SUFFIX_LETTERS], np.int32)
INFIX_CODES = np.array([CP_TO_CODE[c] for c in INFIX_LETTERS], np.int32)

ALEF = CP_TO_CODE[0x0627]
WAW = CP_TO_CODE[0x0648]
YEH = CP_TO_CODE[0x064A]


def normalise(text: str) -> str:
    """Strip diacritics + tatweel, collapse alef variants and taa marbuta
    (paper §3.1 + SNIPPETS Snippet 1).

    Thin wrapper over the shared NORMALISE / DIACRITICS tables — the same
    tables core.textnorm compiles into the segmentation CLASS_LUT, so the
    host string path, the jnp reference, and the Pallas text front-end
    kernel cannot drift (parity-tested per rule in tests/test_textnorm.py).
    """
    out = []
    for ch in text:
        cp = ord(ch)
        if cp in DIACRITICS or cp == TATWEEL:
            continue
        cp = NORMALISE.get(cp, cp)
        out.append(chr(cp))
    return "".join(out)


def encode_word(word: str) -> np.ndarray:
    """One word -> int32[MAXLEN] of dense 6-bit codes, left-aligned, 0-padded.

    Words longer than 15 characters are truncated (the paper's register file
    is sized for the longest attested Arabic word, 15 chars).
    """
    word = normalise(word)
    codes = [CP_TO_CODE.get(ord(c), 0) for c in word][: MAXLEN - 1]
    codes += [0] * (MAXLEN - len(codes))
    return np.asarray(codes, dtype=np.int32)


def encode_batch(words: list[str]) -> np.ndarray:
    """Batch of words -> int32[B, MAXLEN]."""
    if not words:
        return np.zeros((0, MAXLEN), np.int32)
    return np.stack([encode_word(w) for w in words])


def decode_word(codes) -> str:
    """int sequence of dense codes -> string (pads dropped)."""
    return "".join(chr(CODE_TO_CP[int(c)]) for c in codes if int(c) != 0)


def pack_key(codes) -> int:
    """Up to 4 dense codes -> int32 key. PAD-extended on the right.

    key = ((c0*64 + c1)*64 + c2)*64 + c3  < 2^24. Key 0 == empty stem.
    """
    cs = list(codes)[:4] + [0] * (4 - len(list(codes)[:4]))
    k = 0
    for c in cs:
        k = k * 64 + int(c)
    return k


def unpack_key(key: int) -> list[int]:
    cs = []
    for _ in range(4):
        cs.append(key % 64)
        key //= 64
    return cs[::-1]
