"""Arabic verb-form generator (corpus synthesis with ground-truth roots).

The paper validates against the Holy Quran text; that corpus is not shipped
here, so we synthesise a corpus by *generating* verb forms from known roots
using the morphological patterns of the paper's Tables 1-2:

  - past / present / future tense affixes (person + number + gender),
  - proclitics (و ف + interrogative أ + future س),
  - object-pronoun enclitics (ه ها هم كم نا ني ..),
  - form III (فاعل — the ا infix the Remove-Infix pass targets),
  - form X (استفعل — the است prefix of أفاستسقيناكموها),
  - hollow-verb conversion (middle و/ي → ا in the past: قول → قال),
  - defective-verb final-vowel alternation (سقي → سقى / يسقو).

Every generated surface form carries its ground-truth root, enabling exact
accuracy measurement (Table 6/7 analogue).
"""
from __future__ import annotations

import itertools

WAW, YEH, ALEF = "و", "ي", "ا"

PAST_SUFFIXES = ["", "ت", "نا", "تم", "تن", "وا", "ا", "تا", "ن"]
PRESENT_PREFIXES = ["ي", "ت", "ن", "ا"]
PRESENT_SUFFIXES = ["", "ون", "ان", "ين", "ن"]
PAST_PROCLITICS = ["", "و", "ف", "ا"]
PRESENT_PROCLITICS = ["", "و", "ف", "س", "وس", "فس", "ا", "اف"]
OBJECT_SUFFIXES = ["", "ه", "ها", "هم", "كم", "ني", "نا", "كموها"]


def _is_hollow(root: str) -> bool:
    return len(root) == 3 and root[1] in (WAW, YEH)


def _is_defective(root: str) -> bool:
    return len(root) == 3 and root[2] in (WAW, YEH, ALEF)


def conjugate(root: str, rich: bool = True) -> list[tuple[str, str]]:
    """All generated (surface_form, tag) pairs for one root.

    Tags record the morphological derivation for analysis:
    past / present / form3 / form10 / hollow_past / ...
    """
    out: list[tuple[str, str]] = []
    tri = len(root) == 3

    past_stems = [(root, "past")]
    present_stems = [(root, "present")]
    if tri and _is_hollow(root):
        past_stems.append((root[0] + ALEF + root[2], "hollow_past"))
        # 1st/2nd person past drops the middle radical entirely: قلت, كنت
        past_stems.append((root[0] + root[2], "hollow_short_past"))
    if tri and _is_defective(root):
        past_stems.append((root[:2] + "ى", "defective_past"))
    if tri and rich:
        past_stems.append((root[0] + ALEF + root[1] + root[2], "form3"))
        past_stems.append(("است" + root, "form10"))
        present_stems.append((root[0] + ALEF + root[1] + root[2], "form3_present"))
        present_stems.append(("ست" + root, "form10_present"))

    for (stem, tag), proc, suf in itertools.product(
        past_stems, PAST_PROCLITICS, PAST_SUFFIXES
    ):
        if tag == "hollow_short_past" and suf == "":
            continue  # the short stem only ever occurs with a person suffix
        out.append((proc + stem + suf, tag))

    for (stem, tag), proc, pre, suf in itertools.product(
        present_stems, PRESENT_PROCLITICS, PRESENT_PREFIXES, PRESENT_SUFFIXES
    ):
        out.append((proc + pre + stem + suf, tag))

    if rich:
        base = [w for w, t in out if t in ("past", "present")][:24]
        out.extend((w + obj, "object") for w in base for obj in OBJECT_SUFFIXES[1:4])
    return out


def conjugation_table(root: str) -> dict[str, list[str]]:
    """Grouped view (debugging / docs): tag -> forms."""
    table: dict[str, list[str]] = {}
    for w, t in conjugate(root):
        table.setdefault(t, []).append(w)
    return table
