"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle reuses the core (already pyref-validated) JAX implementation so
kernel tests close the chain: pyref (python spec) == core jnp == Pallas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pyref, stemmer


def dict_match_ref(keys: jnp.ndarray, dict_keys: jnp.ndarray) -> jnp.ndarray:
    """Oracle for stem_match.dict_match_pallas."""
    return stemmer.match_dense(keys, dict_keys)


def stem_datapath_ref(words: jnp.ndarray):
    """Oracle for stem_datapath.stem_datapath_pallas: (keys, valid) [B,32]."""
    from repro.core import alphabet as ab

    tri, tri_valid, quad, quad_valid = stemmer.generate_stems(words)
    zero = jnp.zeros_like(tri[..., 0])

    restored = tri.at[..., 1].set(
        jnp.where(tri[..., 1] == ab.ALEF, ab.WAW, tri[..., 1])
    )
    r_valid = tri_valid & (tri[..., 1] == ab.ALEF)

    infix_codes = jnp.asarray(ab.INFIX_CODES)
    is_inf_q = (quad[..., 1:2] == infix_codes).any(-1)
    deinf_q = jnp.stack([quad[..., 0], quad[..., 2], quad[..., 3], zero], -1)
    is_inf_t = (tri[..., 1:2] == infix_codes).any(-1)
    deinf_t = jnp.stack([tri[..., 0], tri[..., 2], zero, zero], -1)

    keys = jnp.concatenate(
        [
            stemmer.pack_keys(tri),
            stemmer.pack_keys(quad),
            stemmer.pack_keys(restored),
            stemmer.pack_keys(deinf_q),
            stemmer.pack_keys(deinf_t),
            jnp.zeros((words.shape[0], 2), jnp.int32),
        ],
        axis=1,
    )
    valid = jnp.concatenate(
        [
            tri_valid,
            quad_valid,
            r_valid,
            quad_valid & is_inf_q,
            tri_valid & is_inf_t,
            jnp.zeros((words.shape[0], 2), bool),
        ],
        axis=1,
    ).astype(jnp.int32)
    return keys, valid


# re-export: candidate slot -> source tag, shared with ops.extract_roots
GROUP_TAGS = [
    pyref.SRC_TRI,
    pyref.SRC_QUAD,
    pyref.SRC_RESTORED,
    pyref.SRC_DEINFIX_TRI,
    pyref.SRC_DEINFIX_BI,
]


def flash_attention_ref(q, k, v, causal: bool = True):
    """Oracle for flash_attention.flash_attention: plain softmax attention.

    q/k/v [B,H,T,D] -> [B,H,T,D], fp32 internals.
    """
    b, h, t, d = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
