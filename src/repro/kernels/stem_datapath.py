"""Pallas TPU kernel: fused stemmer datapath (stages 1-4 + key packing).

The FPGA Datapath (paper Fig 10) separates five functional stages with
register arrays; values never leave the chip between stages. The TPU
analogue keeps a word tile resident in VMEM and runs all character-level
stages back-to-back — check, produce (masking networks), generate
(truncation grid), filter, infix transforms, key packing — emitting the 30
packed candidate keys + validity flags per word. Stage 5 (Compare) is the
separate ``stem_match`` kernel, mirroring the paper's split between the
truncation logic and the comparator banks. (``stem_fused`` goes further
and fuses stage 5 into the same launch; it shares this module's
``candidate_columns`` datapath body.)

The masking networks are implemented as unrolled AND chains over the 16
character slots — a literal transcription of the FPGA combinational
network (and TPU-safe: no dynamic control flow, pure VPU ops).

Candidate layout along the 32-wide output (30 used, 2 zero pads), matching
repro.core.stemmer group order:
  [ 0: 6)  trilateral     (dict: tri)
  [ 6:12)  quadrilateral  (dict: quad)
  [12:18)  restored ا→و   (dict: tri)
  [18:24)  remove-infix quad→tri (dict: tri)
  [24:30)  remove-infix tri→bi   (dict: bi)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import alphabet as ab

N_GROUPS = 5
N_CAND = 6
N_OUT = 32  # 30 candidates padded to a power-of-two minor dim


def _member(x, codes) -> jnp.ndarray:
    """Unrolled membership test against a static code list (VPU OR-chain)."""
    hit = jnp.zeros(x.shape, dtype=bool)
    for c in codes:
        hit |= x == int(c)
    return hit


def candidate_columns(w: jnp.ndarray):
    """Stages 1-4 on a resident word tile: the shared datapath body.

    w int32[bb, 16] -> (key_cols, val_cols): two lists of 30 int32[bb]
    columns in the group order documented above. Pure VPU ops (unrolled
    AND/OR chains, no dynamic control flow), callable from any Pallas
    kernel that holds a word tile in VMEM — both the standalone datapath
    kernel below and the stage 1-5 megakernel (stem_fused) reuse it.
    """
    bb = w.shape[0]
    in_word = w != 0
    n = in_word.astype(jnp.int32).sum(axis=1, keepdims=True)  # (bb, 1)

    # ---- stage 1+2: prefix run (unrolled AND chain + ي terminator) -------
    pp_cols = []
    run = jnp.ones((bb,), dtype=bool)
    seen_yeh = jnp.zeros((bb,), dtype=bool)
    for i in range(5):
        ci = w[:, i]
        run = run & _member(ci, ab.PREFIX_CODES) & ~seen_yeh
        pp_cols.append(run)
        seen_yeh = seen_yeh | (ci == int(ab.YEH))
    # pp[i] == chars 0..i form a valid prefix run

    # ---- stage 1+2: suffix run anchored at the word end ------------------
    is_suf = _member(w, ab.SUFFIX_CODES) | ~in_word
    ps_cols = [None] * ab.MAXLEN
    run = jnp.ones((bb,), dtype=bool)
    for j in range(ab.MAXLEN - 1, -1, -1):
        run = run & is_suf[:, j]
        ps_cols[j] = run
    # valid suffix start s in 0..16: s == n (no suffix) or run holds at s
    nn = n[:, 0]

    def valid_s(s: int) -> jnp.ndarray:
        if s >= ab.MAXLEN:
            return nn == s
        return (nn == s) | ((s < nn) & ps_cols[s] & in_word[:, s])

    # ---- stages 3+4: truncation grid + filter + pack ---------------------
    def pack(c0, c1, c2, c3):
        return ((c0 * 64 + c1) * 64 + c2) * 64 + c3

    zero = jnp.zeros((bb,), jnp.int32)
    tri_k, tri_v, quad_k, quad_v = [], [], [], []
    rest_k, rest_v, dq_k, dq_v, dt_k, dt_v = [], [], [], [], [], []
    for p in range(-1, 5):
        start = p + 1
        p_ok = jnp.ones((bb,), bool) if p == -1 else pp_cols[p]
        c = [w[:, start + k] for k in range(4)]

        tv = p_ok & valid_s(p + 4)
        tri_k.append(pack(c[0], c[1], c[2], zero))
        tri_v.append(tv)
        qv = p_ok & valid_s(p + 5)
        quad_k.append(pack(c[0], c[1], c[2], c[3]))
        quad_v.append(qv)

        # infix transforms (paper Figs 18-19) fused into the same pass
        rest_k.append(pack(c[0], jnp.full_like(c[1], int(ab.WAW)), c[2], zero))
        rest_v.append(tv & (c[1] == int(ab.ALEF)))
        is_inf = _member(c[1], ab.INFIX_CODES)
        dq_k.append(pack(c[0], c[2], c[3], zero))
        dq_v.append(qv & is_inf)
        dt_k.append(pack(c[0], c[2], zero, zero))
        dt_v.append(tv & is_inf)

    key_cols = tri_k + quad_k + rest_k + dq_k + dt_k
    val_cols = [v.astype(jnp.int32) for v in tri_v + quad_v + rest_v + dq_v + dt_v]
    return key_cols, val_cols


def _datapath_kernel(words_ref, keys_ref, valid_ref):
    w = words_ref[...]  # (bb, 16) int32
    key_cols, val_cols = candidate_columns(w)
    zero = jnp.zeros((w.shape[0],), jnp.int32)
    keys_ref[...] = jnp.stack(key_cols + [zero, zero], axis=1)
    valid_ref[...] = jnp.stack(val_cols + [zero, zero], axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def stem_datapath_pallas(
    words: jnp.ndarray, *, block_b: int = 256, interpret: bool = False
):
    """words int32[B,16] -> (keys int32[B,32], valid int32[B,32])."""
    b = words.shape[0]
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    grid = (wp.shape[0] // block_b,)
    keys, valid = pl.pallas_call(
        _datapath_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, ab.MAXLEN), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_b, N_OUT), lambda i: (i, 0)),
            pl.BlockSpec((block_b, N_OUT), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((wp.shape[0], N_OUT), jnp.int32),
            jax.ShapeDtypeStruct((wp.shape[0], N_OUT), jnp.int32),
        ],
        interpret=interpret,
    )(wp)
    return keys[:b], valid[:b]
