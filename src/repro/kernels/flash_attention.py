"""Pallas TPU kernel: fused causal attention (FlashAttention-style).

TPU adaptation of the IO-aware attention algorithm [arXiv:2205.14135]:
instead of SRAM-per-SM tiles, q/k/v blocks are staged HBM->VMEM by
BlockSpec; the MXU consumes (block_q x head_dim) @ (head_dim x block_k)
tiles and the online-softmax running stats (m, l) live in VMEM scratch
across the k-grid. Causality is exploited structurally: k-blocks strictly
above the diagonal are skipped via pl.when (their contribution is zero),
halving compute for long sequences.

Grid: (batch*heads, q_blocks, k_blocks) with k innermost so the output
tile revisits accumulate in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, block_q, block_k, scale, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:  # skip blocks strictly above the diagonal
        run = ki * block_k <= qi * block_q + block_q - 1

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = (q @ k.T) * scale                       # [bq, bk]
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG)

        m_prev = m_ref[...]                          # [bq, 1]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)              # [bq, 1]
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q/k/v: [B, H, T, D] (same T; GQA expansion happens in the caller).

    Returns [B, H, T, D] = softmax(qk^T * D^-0.5 [+causal]) v.
    """
    b, h, t, d = q.shape
    assert k.shape == v.shape == (b, h, t, d)
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    assert t % block_q == 0 and t % block_k == 0, (t, block_q, block_k)
    scale = d ** -0.5

    qf = q.reshape(b * h, t, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    grid = (b * h, t // block_q, t // block_k)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, scale=scale,
        causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, d)
