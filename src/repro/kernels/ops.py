"""Public jit'd wrappers for the Pallas kernels.

On CPU hosts (this container) kernels run with ``interpret=True`` — the
kernel body executes in Python with numpy semantics, validating the exact
code that pallas_call lowers for TPU. On TPU backends interpret=False.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pyref
from repro.core import stemmer as core_stemmer
from repro.kernels import ref as kref
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_fused as sf
from repro.kernels import stem_match as sm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# -- dispatch accounting -----------------------------------------------------
# ``pallas_call`` dispatches issued through the extract_roots_* wrappers
# since the last reset. A Python counter inside a jitted function would
# only tick at trace time, so each wrapper adds what its configuration is
# *known* to launch (stem_fused.planned_launches mirrors the kernel's
# chunking exactly). The launch_overhead benchmark and the megabatch
# launch-count tests read this.
_dispatches = 0


def reset_dispatch_count() -> None:
    """Zero the pallas_call dispatch counter."""
    global _dispatches
    _dispatches = 0


def dispatch_count() -> int:
    """pallas_call dispatches issued through extract_roots_fused /
    extract_roots_persistent / extract_roots_sharded since the last
    :func:`reset_dispatch_count`."""
    return _dispatches


def _count_dispatches(n: int) -> None:
    global _dispatches
    _dispatches += n


def dict_match(keys: jnp.ndarray, dict_keys: jnp.ndarray, *,
               strategy: str = "bank", **kw) -> jnp.ndarray:
    """Membership of packed stem keys in a packed root dictionary.

    strategy="bank"    tiled all-pairs compare (the paper's comparator
                       banks; dict streamed tile-by-tile over the grid)
    strategy="bsearch" in-kernel unrolled binary search over the sorted
                       dictionary (the paper's §7 tree-search upgrade;
                       dict VMEM-resident)
    """
    kw.setdefault("interpret", _interpret_default())
    if strategy == "bank":
        return sm.dict_match_pallas(keys, dict_keys, **kw)
    if strategy == "bsearch":
        kw.pop("block_r", None)  # bsearch holds the whole dict resident
        return sm.dict_match_bsearch_pallas(keys, dict_keys, **kw)
    raise ValueError(f"unknown match strategy: {strategy}")


def stem_candidates(words: jnp.ndarray, **kw):
    """Fused stages 1-4: words[B,16] -> (keys[B,32], valid[B,32])."""
    kw.setdefault("interpret", _interpret_default())
    return sdp.stem_datapath_pallas(words, **kw)


def unpack_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """int32[...] packed keys -> int32[..., 4] char codes."""
    return jnp.stack(
        [(keys >> 18) & 63, (keys >> 12) & 63, (keys >> 6) & 63, keys & 63],
        axis=-1,
    )


@functools.partial(jax.jit,
                   static_argnames=("block_w", "max_words", "interpret"))
def _text_to_words_jit(chars, *, block_w, max_words, interpret):
    from repro.core import textnorm as tn
    from repro.kernels import text_frontend as tf

    geo = tn.segment_geometry(chars, block_w=block_w, max_words=max_words)
    words = tf.text_frontend_pallas(chars, geo.starts, geo.lens,
                                    block_w=block_w, interpret=interpret)
    return words, geo.spans, geo.n_words


def text_to_words(chars, *, block_w: int = 128,
                  max_words: int | None = None,
                  interpret: bool | None = None):
    """Text front-end launch: codepoint tile int32[T] (0-padded) ->
    (words int32[Wp, 16], spans int32[Wp, 2], n_words int32).

    One pallas_call (kernels/text_frontend.py) preceded by the jnp
    segmentation-geometry pre-pass in the same jit scope — the visit-index
    pattern: word starts/lengths/byte spans come from XLA scatters, the
    dense per-word normalise/strip/pack work runs in the kernel. Rows at
    and past ``n_words`` are zero; bit-identical to
    ``textnorm.analyze_text_py`` on the decoded text.
    """
    if interpret is None:
        interpret = _interpret_default()
    _count_dispatches(1)
    return _text_to_words_jit(jnp.asarray(chars, jnp.int32),
                              block_w=block_w, max_words=max_words,
                              interpret=interpret)


def extract_roots_text(chars, roots, *, block_w: int = 128,
                       max_words: int | None = None, infix: bool = True,
                       match: str = "bsearch", block_b: int | None = None,
                       residency: str = "auto", dict_block_r: int = 8,
                       num_buffers: int = 2, skip_index: bool = True,
                       visit_budget: int | None = None,
                       interpret: bool | None = None):
    """Bytes in, roots out: codepoint tile -> (roots int32[Wp, 4],
    sources int32[Wp], spans int32[Wp, 2], n_words int32).

    Chains the text front-end kernel straight into the stemmer megakernel
    — the word tiles stay on device between the two launches (and the
    visit-index pre-pass consumes them there), so there is no host
    round-trip at the text/stemmer boundary. block_b defaults to block_w
    so the front end's padded word rows feed the megakernel without
    re-tiling. Rows past ``n_words`` come from all-zero words and carry
    SRC_NONE.
    """
    words, spans, n_words = text_to_words(chars, block_w=block_w,
                                          max_words=max_words,
                                          interpret=interpret)
    root, source = extract_roots_fused(
        words, roots, infix=infix, match=match,
        block_b=block_b or block_w, residency=residency,
        dict_block_r=dict_block_r, num_buffers=num_buffers,
        skip_index=skip_index, visit_budget=visit_budget,
        interpret=interpret)
    return root, source, spans, n_words


def extract_roots_fused(words, roots, *, infix: bool = True,
                        match: str = "bsearch", block_b: int = 256,
                        residency: str = "auto", dict_block_r: int = 8,
                        num_buffers: int = 2, skip_index: bool = True,
                        visit_budget: int | None = None,
                        with_checksum: bool = False,
                        interpret: bool | None = None):
    """Megabatch megakernel: all five stages, the grid's batch axis
    spanning every [block_b, 16] tile of the (arbitrarily deep) batch, in
    ONE pallas_call (stem_fused.py). Same contract as
    repro.core.stemmer.extract_roots; bit-identical output.

    residency: "resident" keeps the packed dictionaries in VMEM across
    the batch sweep, "streamed" sweeps a scalar-prefetched visit list of
    (dict_block_r x 128) dictionary tiles through an explicit
    ``num_buffers``-deep DMA ladder (unbounded dictionary size; with
    ``skip_index`` only tiles a live candidate key can land in are
    visited), "auto" (default) streams only past
    stem_fused.MAX_RESIDENT_KEYS. Streamed megabatches whose
    scalar-prefetch visit table would exceed ``visit_budget`` (default
    stem_fused.VISIT_SMEM_BUDGET int32 entries) chunk along the batch
    axis into several pallas_calls — ``dispatch_count()`` reflects the
    actual launch count either way.

    roots accepts plain RootDictArrays or a pre-resolved
    core.stemmer.ResolvedRootDict handle (serving path): the handle's
    pinned residency overrides the residency argument and its prebuilt
    tile stream skips the per-call pad/concat, so dictionary hot swaps
    with matching shapes never re-trace.

    ``with_checksum=True`` returns ``(root, source, checksums)`` with the
    per-tile integrity row of :func:`tile_checksum` computed in the SAME
    jit scope as the launch (rows must be a multiple of block_b) — the
    serving path's retire-side verification pays no extra XLA dispatch.
    """
    if interpret is None:
        interpret = _interpret_default()
    _count_dispatches(sf.planned_launches(
        words.shape[0], roots, infix=infix, block_b=block_b,
        residency=residency, dict_block_r=dict_block_r,
        visit_budget=visit_budget))
    if with_checksum:
        return _stem_cs_call(words, roots, 0, infix=infix, match=match,
                             block_b=block_b, residency=residency,
                             dict_block_r=dict_block_r,
                             num_buffers=num_buffers,
                             skip_index=skip_index, persistent=False,
                             visit_budget=visit_budget, interpret=interpret)
    return sf.stem_fused_pallas(words, roots, infix=infix, match=match,
                                block_b=block_b, residency=residency,
                                dict_block_r=dict_block_r,
                                num_buffers=num_buffers,
                                skip_index=skip_index,
                                visit_budget=visit_budget,
                                interpret=interpret)


def extract_roots_persistent(words, roots, *, infix: bool = True,
                             match: str = "bsearch", block_b: int = 256,
                             residency: str = "auto", dict_block_r: int = 8,
                             num_buffers: int = 2, skip_index: bool = True,
                             version_slot=0, visit_budget: int | None = None,
                             with_checksum: bool = False,
                             interpret: bool | None = None):
    """Persistent serving kernel: ONE launch whose body fori_loops over a
    scalar-prefetched work-descriptor ring of batch tiles, DMA-ing word
    tiles in and (root, source) tiles out (stem_fused.py,
    ``persistent=True``). Returns ``(root, source, flags)`` — flags
    int32[batch_tiles] is ``1 + version_slot`` per retired descriptor,
    the completion word the serving ring polls. Roots/sources are
    bit-identical to :func:`extract_roots_fused`. ``with_checksum=True``
    appends the :func:`tile_checksum` row, fused into the launch's jit
    scope.
    """
    if interpret is None:
        interpret = _interpret_default()
    _count_dispatches(sf.planned_launches(
        words.shape[0], roots, infix=infix, block_b=block_b,
        residency=residency, dict_block_r=dict_block_r, persistent=True,
        visit_budget=visit_budget))
    if with_checksum:
        return _stem_cs_call(words, roots, version_slot, infix=infix,
                             match=match, block_b=block_b,
                             residency=residency,
                             dict_block_r=dict_block_r,
                             num_buffers=num_buffers,
                             skip_index=skip_index, persistent=True,
                             visit_budget=visit_budget, interpret=interpret)
    return sf.stem_fused_pallas(words, roots, infix=infix, match=match,
                                block_b=block_b, residency=residency,
                                dict_block_r=dict_block_r,
                                num_buffers=num_buffers,
                                skip_index=skip_index, persistent=True,
                                version_slot=version_slot,
                                visit_budget=visit_budget,
                                interpret=interpret)


def extract_roots_sharded(words, roots, mesh, *, axis: str = "data",
                          infix: bool = True, match: str = "bsearch",
                          block_b: int = 256, residency: str = "auto",
                          dict_block_r: int = 8, num_buffers: int = 2,
                          skip_index: bool = True,
                          visit_budget: int | None = None,
                          with_checksum: bool = False,
                          interpret: bool | None = None):
    """Megakernel launch data-sharded over ``mesh[axis]``: the batch —
    including a multi-tile megabatch — is split into per-device shards
    whose grid spans every local [block_b, 16] tile, the packed
    dictionaries replicated. Same contract as :func:`extract_roots_fused`
    — bit-identical, ragged batches padded and sliced back (including the
    ``with_checksum=True`` integrity row, fused into the sharded jit
    scope). This is the serving path behind
    ``StemmerWorkload(data_devices=N)``.
    """
    from repro.dist import mesh_axis_size, shard_batch  # lazy

    if interpret is None:
        interpret = _interpret_default()
    n_dev = mesh_axis_size(mesh, axis)
    per_dev = -(-words.shape[0] // n_dev) if words.shape[0] else 0
    _count_dispatches(n_dev * sf.planned_launches(
        per_dev, roots, infix=infix, block_b=block_b, residency=residency,
        dict_block_r=dict_block_r, visit_budget=visit_budget))
    return shard_batch(words, roots, mesh, axis=axis, infix=infix,
                       match=match, block_b=block_b, residency=residency,
                       dict_block_r=dict_block_r, num_buffers=num_buffers,
                       skip_index=skip_index, visit_budget=visit_budget,
                       with_checksum=with_checksum, interpret=interpret)


# ---------------------------------------------------------------------------
# Retire-side integrity: a device-computed checksum row per block_b tile
# ---------------------------------------------------------------------------
# odd int32 weights; position term makes the fold order-sensitive inside
# a tile, so swapped rows are detected, not just flipped values
_CS_WEIGHTS = (1000003, 999983, 65599, 31337, 271829, 69069)
_CS_ROOT_W = np.array(_CS_WEIGHTS[:4], np.int32)   # host-fold constants
_CS_SRC_W = np.int32(_CS_WEIGHTS[4])


def _checksum_rows(roots, sources, block_b: int):
    """Traceable checksum body, shared by :func:`tile_checksum` and the
    ``with_checksum`` launch fusions (here and dist.shard_batch)."""
    w = _CS_WEIGHTS
    r = roots.astype(jnp.int32)
    s = sources.astype(jnp.int32).reshape(-1)
    idx = jnp.arange(r.shape[0], dtype=jnp.int32) % block_b
    row = (r[:, 0] * w[0] + r[:, 1] * w[1] + r[:, 2] * w[2]
           + r[:, 3] * w[3] + s * w[4] + idx * w[5] + 1)
    return row.reshape(-1, block_b).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def tile_checksum(roots, sources, *, block_b: int):
    """Per-tile int32 checksum over a launch's (roots, sources) outputs.

    roots int32[rows, 4], sources int32[rows], rows a multiple of
    block_b -> int32[rows // block_b]. The serving path computes it in
    the SAME jit scope as the launch (``with_checksum=True`` on the
    extract_roots_* wrappers, so integrity costs no extra XLA dispatch);
    :func:`tile_checksum_host` re-derives it from the host copies at
    retire, so a torn readback or corrupted transfer fails loudly into
    the retry path instead of serving garbage. Int32 wraparound
    arithmetic, bit-exact between XLA and numpy.
    """
    return _checksum_rows(roots, sources, block_b)


@functools.partial(
    jax.jit,
    static_argnames=("infix", "match", "block_b", "residency",
                     "dict_block_r", "num_buffers", "skip_index",
                     "persistent", "visit_budget", "interpret"))
def _stem_cs_call(words, roots, version_slot, *, infix, match, block_b,
                  residency, dict_block_r, num_buffers, skip_index,
                  persistent, visit_budget, interpret):
    """stem_fused_pallas + per-tile checksum traced into ONE XLA program
    (the separate tile_checksum dispatch cost ~20% of a small serve
    drain). version_slot is traced so hot swaps replay the cache."""
    out = sf.stem_fused_pallas(words, roots, infix=infix, match=match,
                               block_b=block_b, residency=residency,
                               dict_block_r=dict_block_r,
                               num_buffers=num_buffers,
                               skip_index=skip_index, persistent=persistent,
                               version_slot=version_slot,
                               visit_budget=visit_budget,
                               interpret=interpret)
    return out + (_checksum_rows(out[0], out[1], block_b),)


@functools.lru_cache(maxsize=64)
def _cs_host_pos_term(rows: int, block_b: int) -> np.ndarray:
    """Precomputed ``idx * w5 + 1`` term of the host checksum — the
    retire path recomputes the checksum per tile, so the constant
    position fold is cached per (rows, block_b)."""
    idx = (np.arange(rows, dtype=np.int32) % block_b).astype(np.int32)
    return idx * np.int32(_CS_WEIGHTS[5]) + np.int32(1)


def tile_checksum_host(roots, sources, *, block_b: int) -> np.ndarray:
    """Numpy mirror of :func:`tile_checksum` (same int32 wraparound
    math; the matmul and sum force dtype=int32 because numpy would
    otherwise accumulate in int64). Runs on every serve retire, so the
    fold is a single int32 matvec plus cached constants."""
    r = np.asarray(roots).astype(np.int32, copy=False)
    s = np.asarray(sources).astype(np.int32, copy=False).reshape(-1)
    row = r @ _CS_ROOT_W + s * _CS_SRC_W
    row += _cs_host_pos_term(r.shape[0], block_b)
    return row.reshape(-1, block_b).sum(axis=1, dtype=np.int32)


# ---------------------------------------------------------------------------
# Corpus indexing: stemmer megakernel -> postings reduction, one jit scope
# ---------------------------------------------------------------------------
def _root_ids(root, source, vocab):
    """(root[W,4], source[W]) -> vocab ids int32[W]; unmatched/padding
    words get the drop bucket id ``n_roots = vocab.shape[0]``."""
    n_roots = vocab.shape[0]
    key = core_stemmer.pack_keys(root)
    idx = jnp.searchsorted(vocab, key).astype(jnp.int32)
    found = (jnp.take(vocab, jnp.minimum(idx, n_roots - 1), mode="clip")
             == key)
    valid = found & (source != pyref.SRC_NONE)
    return jnp.where(valid, idx, n_roots)


@functools.partial(
    jax.jit,
    static_argnames=("infix", "match", "block_b", "residency",
                     "dict_block_r", "num_buffers", "skip_index",
                     "visit_budget", "block_w", "interpret"))
def _index_jit(words, roots, vocab, doc_ids, positions, *, infix, match,
               block_b, residency, dict_block_r, num_buffers, skip_index,
               visit_budget, block_w, interpret):
    from repro.kernels import postings as pk

    root, source = sf.stem_fused_pallas(
        words, roots, infix=infix, match=match, block_b=block_b,
        residency=residency, dict_block_r=dict_block_r,
        num_buffers=num_buffers, skip_index=skip_index,
        visit_budget=visit_budget, interpret=interpret)
    ids = _root_ids(root, source, vocab)
    hist, rank = pk.postings_pallas(ids, n_roots=vocab.shape[0],
                                    block_w=block_w, interpret=interpret)
    return pk.finish_postings(hist, rank, ids, doc_ids, positions,
                              n_roots=vocab.shape[0], block_w=block_w)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "infix", "match", "block_b",
                     "residency", "dict_block_r", "num_buffers",
                     "skip_index", "visit_budget", "block_w", "interpret"))
def _index_sharded_jit(words, roots, vocab, doc_ids, positions, *, mesh,
                       axis, infix, match, block_b, residency, dict_block_r,
                       num_buffers, skip_index, visit_budget, block_w,
                       interpret):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist import mesh_axis_size
    from repro.kernels import postings as pk

    n_dev = mesh_axis_size(mesh, axis)
    w = words.shape[0]
    # per-device slices must be whole postings tiles so the stacked
    # per-shard (tile, root) histograms keep global corpus order
    pad = (-w) % (n_dev * block_w)
    wp = jnp.pad(words, ((0, pad), (0, 0)))   # zero rows -> SRC_NONE -> drop

    def local(wds, r, v):
        root, source = sf.stem_fused_pallas(
            wds, r, infix=infix, match=match, block_b=block_b,
            residency=residency, dict_block_r=dict_block_r,
            num_buffers=num_buffers, skip_index=skip_index,
            visit_budget=visit_budget, interpret=interpret)
        ids = _root_ids(root, source, v)
        hist, rank = pk.postings_pallas(ids, n_roots=v.shape[0],
                                        block_w=block_w, interpret=interpret)
        return hist, rank, ids

    f = shard_map(local, mesh=mesh, in_specs=(P(axis), P(), P()),
                  out_specs=(P(axis), P(axis), P(axis)), check_rep=False)
    hist, rank, ids = f(wp, roots, vocab)
    # the device-side shard merge: corpus shards are contiguous slices,
    # so stacking per-shard tile histograms restores corpus tile order
    # and the global exclusive cumsum in finish_postings *is* the merge
    return pk.finish_postings(hist, rank, ids[:w], doc_ids, positions,
                              n_roots=vocab.shape[0], block_w=block_w)


def build_root_index(words, roots, vocab, doc_ids, positions, *,
                     mesh=None, axis: str = "data", infix: bool = True,
                     match: str = "bsearch", block_b: int = 2048,
                     residency: str = "auto", dict_block_r: int = 8,
                     num_buffers: int = 2, skip_index: bool = True,
                     visit_budget: int | None = None, block_w: int = 2048,
                     interpret: bool | None = None):
    """One corpus chunk -> one inverted-index partial, fully on device.

    words int32[W, 16], vocab int32[n_roots] (sorted packed root keys),
    doc_ids/positions int32[W] -> ``(counts int32[n_roots],
    docs int32[W_pad], poss int32[W_pad], n_postings int32)`` with root
    r's postings at ``[excl_cumsum(counts)[r], +counts[r])``, sorted by
    global word index (CSR layout; see kernels/postings.py).

    Chains the stemmer megakernel straight into the postings reduction
    kernel in ONE jit scope — roots/ids/histograms never visit the host,
    the id map + cumsums + final scatter are XLA ops in the same scope
    (the visit-index pattern), and there is no per-word host loop
    anywhere. With ``mesh`` the word tiles shard over ``mesh[axis]``
    (dictionaries + vocab replicated) and the per-shard (tile, root)
    histograms merge device-side via the same exclusive cumsum that
    merges tiles on one device. ``roots`` accepts plain RootDictArrays
    or a ResolvedRootDict handle, as everywhere.
    """
    if interpret is None:
        interpret = _interpret_default()
    kw = dict(infix=infix, match=match, block_b=block_b,
              residency=residency, dict_block_r=dict_block_r,
              num_buffers=num_buffers, skip_index=skip_index,
              visit_budget=visit_budget, block_w=block_w,
              interpret=interpret)
    words = jnp.asarray(words, jnp.int32)
    vocab = jnp.asarray(vocab, jnp.int32)
    doc_ids = jnp.asarray(doc_ids, jnp.int32)
    positions = jnp.asarray(positions, jnp.int32)
    from repro.kernels import postings as pk

    if mesh is None:
        _count_dispatches(
            sf.planned_launches(words.shape[0], roots, infix=infix,
                                block_b=block_b, residency=residency,
                                dict_block_r=dict_block_r,
                                visit_budget=visit_budget)
            + pk.postings_launches(words.shape[0], block_w=block_w))
        return _index_jit(words, roots, vocab, doc_ids, positions, **kw)
    from repro.dist import mesh_axis_size

    n_dev = mesh_axis_size(mesh, axis)
    per_dev = -(-words.shape[0] // n_dev) if words.shape[0] else 0
    _count_dispatches(n_dev * (
        sf.planned_launches(per_dev, roots, infix=infix, block_b=block_b,
                            residency=residency, dict_block_r=dict_block_r,
                            visit_budget=visit_budget)
        + pk.postings_launches(per_dev, block_w=block_w)))
    return _index_sharded_jit(words, roots, vocab, doc_ids, positions,
                              mesh=mesh, axis=axis, **kw)


def build_root_index_text(chars, roots, vocab, byte_off, *, doc0: int = 0,
                          word0_of_doc0: int = 0, block_w_text: int = 128,
                          max_words: int | None = None, block_w: int = 2048,
                          interpret: bool | None = None, **stem_kw):
    """Raw-text variant: codepoint tile + per-doc byte offsets -> the same
    inverted-index partial as :func:`build_root_index`.

    ``chars`` is a coalesced codepoint tile (textnorm.coalesce_docs),
    ``byte_off`` int64[D] each document's first utf-8 byte offset in it.
    Word->document attribution and in-document positions derive from the
    front end's byte spans as XLA searchsorted/scatter ops in the same
    jit scope — the byte stream goes in, postings come out, still no
    per-word host work. ``doc0`` offsets emitted doc ids for chunked
    corpora; ``word0_of_doc0`` is the global position of the chunk's
    first word inside its (chunk-straddling) first document, 0 when
    documents never straddle chunks.
    """
    if interpret is None:
        interpret = _interpret_default()
    root, source, spans, n_words = extract_roots_text(
        chars, roots, block_w=block_w_text, max_words=max_words,
        interpret=interpret, **stem_kw)
    from repro.kernels import postings as pk

    _count_dispatches(pk.postings_launches(root.shape[0], block_w=block_w))
    # doc0 / word0_of_doc0 ride as traced scalars so chunked corpora
    # replay one trace per tile shape instead of one per chunk
    return _finish_index_text(root, source, spans, n_words, vocab,
                              jnp.asarray(byte_off),
                              jnp.int32(doc0), jnp.int32(word0_of_doc0),
                              block_w=block_w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def _finish_index_text(root, source, spans, n_words, vocab, byte_off,
                       doc0, word0_of_doc0, *, block_w, interpret):
    from repro.kernels import postings as pk

    wp = root.shape[0]
    arange = jnp.arange(wp, dtype=jnp.int32)
    in_tile = arange < n_words
    # byte span start -> owning document (serve/text.py retire path)
    doc_local = (jnp.searchsorted(byte_off, spans[:, 0].astype(byte_off.dtype),
                                  side="right") - 1).astype(jnp.int32)
    doc_local = jnp.maximum(doc_local, 0)
    # first word index per document via scatter-min (invalid rows carry
    # arange >= n_words, so they never win the min)
    n_docs = byte_off.shape[0]
    first = jnp.full((n_docs,), wp, jnp.int32).at[doc_local].min(
        arange, mode="drop")
    positions = arange - jnp.take(first, doc_local, mode="clip")
    positions = jnp.where(doc_local == 0, positions + word0_of_doc0,
                          positions)
    ids = _root_ids(root, source, vocab)
    ids = jnp.where(in_tile, ids, vocab.shape[0])
    hist, rank = pk.postings_pallas(ids, n_roots=vocab.shape[0],
                                    block_w=block_w, interpret=interpret)
    return pk.finish_postings(hist, rank, ids, doc_local + doc0, positions,
                              n_roots=vocab.shape[0], block_w=block_w)


@functools.partial(jax.jit, static_argnames=("infix", "interpret"))
def extract_roots_multilaunch(words, roots, *, infix: bool = True,
                              interpret: bool | None = None):
    """The pre-megakernel pipeline: datapath kernel -> 5 match kernel
    launches -> priority select, with keys/valid/hit masks round-tripping
    through HBM between launches. Kept as the baseline the megakernel is
    benchmarked against (benchmarks/throughput.py).
    """
    if interpret is None:
        interpret = _interpret_default()
    keys, valid = sdp.stem_datapath_pallas(words, interpret=interpret)
    b = words.shape[0]

    n_groups = 5 if infix else 2
    dicts = [roots.tri, roots.quad, roots.tri, roots.tri, roots.bi][:n_groups]
    hits = []
    for g, dk in enumerate(dicts):
        sl = keys[:, g * 6 : (g + 1) * 6].reshape(-1)
        hit = sm.dict_match_pallas(sl, dk, interpret=interpret).reshape(b, 6)
        hits.append(hit & (valid[:, g * 6 : (g + 1) * 6] > 0))
    all_hits = jnp.concatenate(hits, axis=1)

    first = jnp.argmax(all_hits, axis=1)
    found = all_hits.any(axis=1)
    chosen_keys = jnp.take_along_axis(keys[:, : n_groups * 6], first[:, None], 1)[:, 0]
    root = jnp.where(found[:, None], unpack_keys(chosen_keys), 0)
    tags = jnp.asarray(
        [t for t in kref.GROUP_TAGS[:n_groups] for _ in range(6)], jnp.int32
    )
    source = jnp.where(found, tags[first], pyref.SRC_NONE)
    return root, source


def autotune_stem_fused(words, roots, *, infix: bool = True,
                        block_bs=(128, 256, 512), matches=("bank", "bsearch"),
                        residencies=("resident", "streamed"),
                        dict_block_rs=(4, 8, 16),
                        num_bufferss=(1, 2, 4), skip_indexes=(True,),
                        iters: int = 2, interpret: bool | None = None):
    """Time the megakernel over (block_b, match, residency, dict tile rows,
    DMA ladder depth, skip index) and return the best config.

    Returns ``{"block_b": int, "match": str, "residency": str,
    "dict_block_r": int, "num_buffers": int, "skip_index": bool,
    "timings": {(block_b, match, residency, dict_block_r, num_buffers,
    skip_index): seconds}}``. Timings include one warmup (compile) call,
    then ``iters`` measured calls each. Resident configs use
    ``dict_block_r=0`` / ``num_buffers=0`` in the timing key (the knobs
    only exist on the streamed path) and are skipped entirely when the
    dictionaries exceed the VMEM residency budget (counting only the
    tables ``infix`` loads).
    """
    if interpret is None:
        interpret = _interpret_default()
    roots, _, _ = core_stemmer.unwrap_dict(roots)
    resident_ok = (sf.choose_residency(roots, "auto", infix=infix)
                   == "resident")
    timings = {}
    # clamp tiles to the batch (small batches still tune over strategies)
    bbs = sorted({min(bb, words.shape[0]) for bb in block_bs})
    for bb in bbs:
        for m in matches:
            for res in residencies:
                if res == "resident" and not resident_ok:
                    continue
                # dict tiling / ladder depth / skip are no-op knobs on
                # the resident path
                streamed = res == "streamed"
                drs = dict_block_rs if streamed else (0,)
                nbs = num_bufferss if streamed else (0,)
                sks = skip_indexes if streamed else (True,)
                for dr in drs:
                    for nb in nbs:
                        for sk in sks:
                            call = functools.partial(
                                extract_roots_fused, words, roots,
                                infix=infix, match=m, block_b=bb,
                                residency=res, dict_block_r=dr or 8,
                                num_buffers=nb or 2, skip_index=sk,
                                interpret=interpret)
                            jax.block_until_ready(call())  # warmup/compile
                            t0 = time.perf_counter()
                            for _ in range(iters):
                                jax.block_until_ready(call())
                            timings[(bb, m, res, dr, nb, sk)] = (
                                time.perf_counter() - t0) / iters
    if not timings:
        raise ValueError(
            "autotune_stem_fused: no runnable config — the dictionaries"
            f" exceed the VMEM residency budget ({sf.MAX_RESIDENT_KEYS}"
            " keys) and residencies excludes 'streamed'")
    best = min(timings, key=timings.get)
    best_bb, best_m, best_res, best_dr, best_nb, best_sk = best
    return {"block_b": best_bb, "match": best_m, "residency": best_res,
            "dict_block_r": best_dr or 8, "num_buffers": best_nb or 2,
            "skip_index": best_sk, "timings": timings}
