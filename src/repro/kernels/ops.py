"""Public jit'd wrappers for the Pallas kernels.

On CPU hosts (this container) kernels run with ``interpret=True`` — the
kernel body executes in Python with numpy semantics, validating the exact
code that pallas_call lowers for TPU. On TPU backends interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import pyref
from repro.kernels import ref as kref
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_match as sm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def dict_match(keys: jnp.ndarray, dict_keys: jnp.ndarray, **kw) -> jnp.ndarray:
    """Membership of packed stem keys in a packed root dictionary."""
    kw.setdefault("interpret", _interpret_default())
    return sm.dict_match_pallas(keys, dict_keys, **kw)


def stem_candidates(words: jnp.ndarray, **kw):
    """Fused stages 1-4: words[B,16] -> (keys[B,32], valid[B,32])."""
    kw.setdefault("interpret", _interpret_default())
    return sdp.stem_datapath_pallas(words, **kw)


def unpack_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """int32[...] packed keys -> int32[..., 4] char codes."""
    return jnp.stack(
        [(keys >> 18) & 63, (keys >> 12) & 63, (keys >> 6) & 63, keys & 63],
        axis=-1,
    )


@functools.partial(jax.jit, static_argnames=("infix", "interpret"))
def extract_roots_fused(words, roots, *, infix: bool = True, interpret: bool | None = None):
    """Full kernel pipeline: datapath kernel -> match kernels -> priority
    select. Same contract as repro.core.stemmer.extract_roots.
    """
    if interpret is None:
        interpret = _interpret_default()
    keys, valid = sdp.stem_datapath_pallas(words, interpret=interpret)
    b = words.shape[0]

    n_groups = 5 if infix else 2
    dicts = [roots.tri, roots.quad, roots.tri, roots.tri, roots.bi][:n_groups]
    hits = []
    for g, dk in enumerate(dicts):
        sl = keys[:, g * 6 : (g + 1) * 6].reshape(-1)
        hit = sm.dict_match_pallas(sl, dk, interpret=interpret).reshape(b, 6)
        hits.append(hit & (valid[:, g * 6 : (g + 1) * 6] > 0))
    all_hits = jnp.concatenate(hits, axis=1)

    first = jnp.argmax(all_hits, axis=1)
    found = all_hits.any(axis=1)
    chosen_keys = jnp.take_along_axis(keys[:, : n_groups * 6], first[:, None], 1)[:, 0]
    root = jnp.where(found[:, None], unpack_keys(chosen_keys), 0)
    tags = jnp.asarray(
        [t for t in kref.GROUP_TAGS[:n_groups] for _ in range(6)], jnp.int32
    )
    source = jnp.where(found, tags[first], pyref.SRC_NONE)
    return root, source
