"""Pallas TPU kernel: the inverted-index postings reduction.

The corpus indexer (repro.index) needs root -> (doc, position) postings
for millions of words without a host loop. The classic device recipe is
sort + segment-reduce + scatter, and this kernel runs the per-tile half
of it on the accelerator:

  * each grid step takes one ``block_w``-word tile of root ids and sorts
    the composite keys ``id * block_w + lane`` with an in-register
    bitonic network (block_w is a power of two, so the network is a
    static ``log2^2`` cascade of predicated compare-exchanges — no data-
    dependent control flow, same discipline as ``stem_match.bsearch_hit``);
  * bucket boundaries then fall out of a branchless lower-bound search:
    ``log2(block_w)`` bisection steps per query give the per-tile root
    histogram (segment reduce) and, re-run at each word's own composite
    key, its stable rank within its root segment.

Histograms and ranks are tiny next to the word stream, so the global
side of the reduction — exclusive cumsums over (tile, root) and the
final scatter of (doc, position) pairs into the postings array — runs as
XLA ops in the same jit scope (:func:`finish_postings`), exactly the
PR 5/PR 7 visit-index pattern: scatters in XLA, dense per-word work in
the kernel. Composite keys make the sort stable in (tile, lane) order,
so postings within a root come out sorted by global word index with no
tie-breaking pass.

Invalid words (no root found, padding) are assigned the drop bucket
``id == n_roots``; their scatter destinations land out of bounds and
``mode="drop"`` discards them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stem_match import _ceil_log2

LANE = 128

# int32 composite keys: id * block_w + lane must not overflow.
MAX_COMPOSITE = 1 << 31


def _iota(n: int) -> jnp.ndarray:
    """int32[n] 0..n-1 (2D broadcasted_iota — TPU has no 1D iota)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, n), 1).reshape(n)


def _bitonic_sort(keys: jnp.ndarray) -> jnp.ndarray:
    """Ascending bitonic sort of int32[n], n a power of two.

    Fully static network: log2(n)*(log2(n)+1)/2 vectorised compare-
    exchange stages, each a gather at the lane's partner (``lane ^ j``)
    plus a predicated min/max select — branchless, like the bsearch.
    """
    n = keys.shape[0]
    lane = _iota(n)
    for k in (1 << s for s in range(1, _ceil_log2(n) + 1)):
        j = k // 2
        while j:
            partner = jnp.take(keys, lane ^ j, mode="clip")
            up = (lane & k) == 0          # ascending run?
            low = (lane & j) == 0         # lower end of the exchange?
            keys = jnp.where(up == low, jnp.minimum(keys, partner),
                             jnp.maximum(keys, partner))
            j //= 2
    return keys


def _lower_bound(sorted_keys: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Count of elements in sorted int32[n] (n pow2) strictly below q.

    Branchless: ceil(log2 n) predicated bisection steps (the
    ``bsearch_hit`` discipline), then one final adjust for the
    everything-smaller case.
    """
    n = sorted_keys.shape[0]
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, n - 1, jnp.int32)
    for _ in range(_ceil_log2(n)):
        mid = (lo + hi) // 2
        v = jnp.take(sorted_keys, mid, mode="clip")
        ge = v >= q
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, mid + 1)
    return lo + (jnp.take(sorted_keys, lo, mode="clip") < q)


def _postings_kernel(ids_ref, hist_ref, rank_ref, *, block_w, n_roots_pad):
    """Grid (n_tiles,): one word tile -> (root histogram, in-segment rank).

    Composite keys ``id * block_w + lane`` are unique, so the bitonic
    sort needs no stability of its own and the rank of word ``lane`` is
    simply its key's position minus its root segment's start.
    """
    ids = ids_ref[0, :]                                     # (block_w,)
    lane = _iota(block_w)
    keys = ids * block_w + lane
    skeys = _bitonic_sort(keys)
    # segment boundaries at every bucket start r * block_w (one extra
    # query closes the last bucket)
    bounds = _lower_bound(skeys, _iota(n_roots_pad + 1) * block_w)
    hist_ref[0, :] = (bounds[1:] - bounds[:-1]).astype(jnp.int32)
    seg_start = jnp.take(bounds, ids, mode="clip")
    rank_ref[0, :] = _lower_bound(skeys, keys) - seg_start


@functools.partial(jax.jit,
                   static_argnames=("n_roots", "block_w", "interpret"))
def postings_pallas(ids: jnp.ndarray, *, n_roots: int, block_w: int = 2048,
                    interpret: bool = False):
    """Tile-local postings reduction: root ids -> (hist, rank).

    ids int32[W] in [0, n_roots] (== n_roots marks the drop bucket) ->
      hist int32[n_tiles, n_roots + 1]  per-tile root histogram
      rank int32[W_pad]                 stable rank within (tile, root)

    W pads up to a ``block_w`` multiple with drop-bucket ids. One
    pallas_call, grid over word tiles; combine across tiles (and shards)
    with :func:`finish_postings`.
    """
    if block_w & (block_w - 1):
        raise ValueError(f"block_w must be a power of two, got {block_w}")
    n_roots_pad = n_roots + 1                  # +1: the drop bucket
    if n_roots_pad * block_w >= MAX_COMPOSITE:
        raise ValueError(
            f"composite sort keys overflow int32: ({n_roots} roots + drop)"
            f" * block_w {block_w} >= 2^31 — lower block_w")
    w = ids.shape[0]
    pad = (-w) % block_w
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, pad),
                    constant_values=n_roots).reshape(-1, block_w)
    n_tiles = ids_p.shape[0]
    hist, rank = pl.pallas_call(
        functools.partial(_postings_kernel, block_w=block_w,
                          n_roots_pad=n_roots_pad),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, block_w), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, n_roots_pad), lambda i: (i, 0)),
                   pl.BlockSpec((1, block_w), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, n_roots_pad), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, block_w), jnp.int32),
        ],
        interpret=interpret,
    )(ids_p)
    return hist, rank.reshape(-1)


def postings_launches(n_words: int, *, block_w: int = 2048) -> int:
    """pallas_call dispatches one :func:`postings_pallas` call issues —
    always 1 (the grid spans every word tile), 0 for an empty batch."""
    return 1 if n_words else 0


def finish_postings(hist, rank, ids, doc_ids, positions, *, n_roots: int,
                    block_w: int):
    """Global half of the reduction: cumsums + the postings scatter.

    hist int32[n_tiles, n_roots+1], rank int32[W_pad] from one or more
    :func:`postings_pallas` calls over *consecutive* word tiles (the
    sharded path stacks per-shard tiles in corpus order, which makes the
    shard merge the same exclusive cumsum as the tile merge); ids
    int32[W], doc_ids/positions int32[W] aligned with it.

    Returns ``(counts int32[n_roots], docs int32[W_pad],
    poss int32[W_pad], n_postings int32)`` — per-root posting counts,
    and the postings arrays laid out CSR-style: root r's postings occupy
    ``[offsets[r], offsets[r] + counts[r])`` with
    ``offsets = exclusive_cumsum(counts)``, sorted by global word index.
    Entries at and past ``n_postings`` are zero. Pure XLA (cumsums, one
    gather, two scatters) — no per-word host loop.
    """
    w = ids.shape[0]
    w_pad = rank.shape[0]
    # per-(tile, root) base: how many of root r landed in earlier tiles
    tile_base = jnp.cumsum(hist, axis=0) - hist          # exclusive, axis 0
    counts = hist.sum(axis=0)[:n_roots]
    offsets = jnp.cumsum(counts) - counts                # exclusive
    n_postings = counts.sum()

    tile_of = _iota(w) // block_w
    safe_ids = jnp.minimum(ids, n_roots)                 # gather-safe
    base = (jnp.take(jnp.concatenate([offsets, n_postings[None]]), safe_ids,
                     mode="clip")
            + tile_base[tile_of, safe_ids] + rank[:w])
    # drop bucket -> out of bounds -> mode="drop" discards
    dest = jnp.where(safe_ids < n_roots, base, w_pad)
    docs = jnp.zeros((w_pad,), jnp.int32).at[dest].set(
        doc_ids.astype(jnp.int32), mode="drop")
    poss = jnp.zeros((w_pad,), jnp.int32).at[dest].set(
        positions.astype(jnp.int32), mode="drop")
    return counts, docs, poss, n_postings
