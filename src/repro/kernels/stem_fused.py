"""Pallas TPU megakernel: the whole stemmer (stages 1-5) in ONE launch.

The paper's pipelined FPGA processor earns its speedup by keeping every
stage on-chip: values never leave the datapath between Check / Produce /
Generate / Filter / Compare. The previous "fused" TPU path was six
separate ``pallas_call`` launches (1 datapath + 5 dictionary matches)
that round-tripped keys, validity flags and hit masks through HBM. This
kernel is the faithful analogue of the paper's architecture: a word tile
enters VMEM once and ``(root, source)`` comes out — candidates, validity
flags and hit masks live only in registers/VMEM.

Layout (see DESIGN.md §5):
  - the three packed root dictionaries (tri/quad/bi, int32 keys; ~2K
    entries total for realistic dictionaries) ride along as
    VMEM-resident blocks with a constant index map, so the pipeline
    fetches them once and revisits them for every batch tile;
  - stages 1-4 are the shared :func:`stem_datapath.candidate_columns`
    datapath (unrolled AND/OR masking networks, truncation grid, infix
    transforms, 24-bit key packing);
  - stage 5 (Compare) supports two in-kernel strategies:
      match="bank"     all-pairs equality against the dictionary tile —
                       the paper's comparator banks (O(R) per candidate);
      match="bsearch"  unrolled branchless binary search over the sorted
                       dictionary — the paper's §7 proposed tree search
                       (ceil(log2 R) static steps, O(log R) per
                       candidate); see stem_match.bsearch_hit;
  - the priority select (first hit in VHDL candidate order) is a
    cumulative-sum one-hot reduction, so no gather is needed on the
    output side.

Dictionaries large enough to pressure VMEM (>~64K keys) should instead
stream over a minor grid axis double-buffered (the stem_match kernel
shows the pattern); `stem_fused_pallas` asserts the resident budget and
DESIGN.md documents the switch-over.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import alphabet as ab
from repro.core import pyref
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_match as sm

N_CAND = 6
# candidate-group order == stem_datapath layout == core.stemmer priority
GROUP_DICTS = ("tri", "quad", "tri", "tri", "bi")
GROUP_TAGS = (
    pyref.SRC_TRI,
    pyref.SRC_QUAD,
    pyref.SRC_RESTORED,
    pyref.SRC_DEINFIX_TRI,
    pyref.SRC_DEINFIX_BI,
)
# VMEM residency budget for the three dictionaries combined (int32 words).
# Beyond this, switch to the streamed stem_match kernel (DESIGN.md §5.3).
MAX_RESIDENT_KEYS = 1 << 16


def _bank_hit(flat_dict: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """All-pairs comparator bank: keys[bb,6] vs flat_dict[Rp] -> bool[bb,6]."""
    return (keys[..., None] == flat_dict[None, None, :]).any(-1)


def _fused_kernel(words_ref, tri_ref, quad_ref, bi_ref, root_ref, src_ref,
                  *, n_groups: int, match: str):
    w = words_ref[...]                             # (bb, 16) int32
    key_cols, val_cols = sdp.candidate_columns(w)  # stages 1-4, 30 columns
    n_slots = n_groups * N_CAND
    keys = jnp.stack(key_cols[:n_slots], axis=1)   # (bb, n_slots)
    valid = jnp.stack(val_cols[:n_slots], axis=1) > 0

    dicts = {"tri": tri_ref[...].reshape(-1),
             "quad": quad_ref[...].reshape(-1),
             "bi": bi_ref[...].reshape(-1)}

    # ---- stage 5a: Compare — per-group match against the resident dict ---
    hit_cols = []
    for g in range(n_groups):
        kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
        d = dicts[GROUP_DICTS[g]]
        hit_cols.append(sm.bsearch_hit(d, kg) if match == "bsearch"
                        else _bank_hit(d, kg))
    hits = jnp.concatenate(hit_cols, axis=1) & valid   # (bb, n_slots)

    # ---- stage 5b: priority select (first hit in VHDL candidate order) ---
    # One-hot of the first True per row — cumsum==1 on a hit slot — so the
    # winning key/tag fall out of a masked sum, gather-free.
    hits_i = hits.astype(jnp.int32)
    is_first = hits_i * (jnp.cumsum(hits_i, axis=1) == 1)
    chosen = (keys * is_first).sum(axis=1)             # 0 when no hit
    # per-group tag weights are static python ints (no captured constants)
    grp_first = is_first.reshape(-1, n_groups, N_CAND).sum(axis=2)
    source = sum(int(GROUP_TAGS[g]) * grp_first[:, g] for g in range(n_groups))
    root_ref[...] = jnp.stack(
        [(chosen >> 18) & 63, (chosen >> 12) & 63,
         (chosen >> 6) & 63, chosen & 63], axis=1)
    src_ref[...] = source[:, None]


@functools.partial(
    jax.jit, static_argnames=("infix", "match", "block_b", "interpret"))
def stem_fused_pallas(
    words: jnp.ndarray,
    roots,
    *,
    infix: bool = True,
    match: str = "bsearch",
    block_b: int = 256,
    interpret: bool = False,
):
    """words int32[B,16] + RootDictArrays -> (root int32[B,4], source int32[B]).

    Single ``pallas_call``: grid is the batch tiling only; the packed
    dictionaries are VMEM-resident across all grid steps (constant index
    map). Bit-identical to ``core.stemmer.extract_roots`` (and pyref).
    """
    if match not in ("bank", "bsearch"):
        raise ValueError(f"unknown in-kernel match strategy: {match}")
    n_groups = 5 if infix else 2

    total_keys = sum(int(d.shape[0]) for d in (roots.tri, roots.quad, roots.bi))
    if total_keys > MAX_RESIDENT_KEYS:
        raise ValueError(
            f"dictionaries too large for VMEM residency ({total_keys} keys >"
            f" {MAX_RESIDENT_KEYS}); stream stage 5 via stem_match instead"
            " (DESIGN.md §5.3)")

    prep = sm.pad_dict_sorted if match == "bsearch" else sm.pad_dict_lanes
    tri2, quad2, bi2 = prep(roots.tri), prep(roots.quad), prep(roots.bi)

    b = words.shape[0]
    if b == 0:  # degenerate batch: nothing to launch
        return (jnp.zeros((0, 4), jnp.int32), jnp.zeros((0,), jnp.int32))
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    bp = wp.shape[0]
    grid = (bp // block_b,)

    dict_spec = lambda d: pl.BlockSpec(d.shape, lambda i: (0, 0))
    root, source = pl.pallas_call(
        functools.partial(_fused_kernel, n_groups=n_groups, match=match),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, ab.MAXLEN), lambda i: (i, 0)),
            dict_spec(tri2), dict_spec(quad2), dict_spec(bi2),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 4), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 4), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(wp, tri2, quad2, bi2)
    return root[:b], source[:b, 0]
