"""Pallas TPU megakernel: the whole stemmer (stages 1-5) in ONE launch.

The paper's pipelined FPGA processor earns its speedup by keeping every
stage on-chip: values never leave the datapath between Check / Produce /
Generate / Filter / Compare. The previous "fused" TPU path was six
separate ``pallas_call`` launches (1 datapath + 5 dictionary matches)
that round-tripped keys, validity flags and hit masks through HBM. This
kernel is the faithful analogue of the paper's architecture: a word tile
enters VMEM once and ``(root, source)`` comes out — candidates, validity
flags and hit masks live only in registers/VMEM.

Layout (see DESIGN.md §5):
  - the three packed root dictionaries (tri/quad/bi, int32 keys; ~2K
    entries total for realistic dictionaries) ride along as
    VMEM-resident blocks with a constant index map, so the pipeline
    fetches them once and revisits them for every batch tile;
  - stages 1-4 are the shared :func:`stem_datapath.candidate_columns`
    datapath (unrolled AND/OR masking networks, truncation grid, infix
    transforms, 24-bit key packing);
  - stage 5 (Compare) supports two in-kernel strategies:
      match="bank"     all-pairs equality against the dictionary tile —
                       the paper's comparator banks (O(R) per candidate);
      match="bsearch"  unrolled branchless binary search over the sorted
                       dictionary — the paper's §7 proposed tree search
                       (ceil(log2 R) static steps, O(log R) per
                       candidate); see stem_match.bsearch_hit;
  - the priority select (first hit in VHDL candidate order) is a
    cumulative-sum one-hot reduction, so no gather is needed on the
    output side.

Dictionaries large enough to pressure VMEM (>~64K keys) take the
*streamed* Compare path (DESIGN.md §5.3), an explicitly pipelined sweep:

  - a jnp pre-pass (stages 1-4 on the padded batch, shared
    ``candidate_columns`` body) computes where every batch tile's live
    candidate keys land among the sorted `(dict_block_r x 128)`
    dictionary tiles, and emits a per-batch-tile **tile-visit index** —
    only tiles that can contain a hit are visited, not all of them. The
    index and per-tile visit counts reach the kernel through scalar
    prefetch (``pltpu.PrefetchScalarGridSpec``), so tile ids are
    available for DMA issue before the compute touches them.
  - the dictionary stream stays in HBM (``memory_space=ANY``) and the
    kernel drives its own multi-buffered ``pltpu.make_async_copy``
    ladder (``num_buffers`` deep): the DMA for visit k+num_buffers-1 is
    started before visit k's bsearch/bank compare runs, replacing the
    implicit single-stage Pallas pipeline of the previous layout. An
    OR-accumulating hit mask persists in VMEM scratch across the sweep;
    the priority select runs once per batch tile after it.

`residency="resident"|"streamed"|"auto"` selects the layout; "auto"
streams once the packed dictionaries exceed MAX_RESIDENT_KEYS
(counting only the tables the sweep loads: bi is excluded for
infix=False). `skip_index=False` degrades the visit index to the full
sweep — same kernel, every live tile visited — which is the baseline the
`dict_stream_pipeline` benchmark section compares against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import alphabet as ab
from repro.core import pyref
from repro.core import stemmer as core_stemmer
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_match as sm

N_CAND = 6
# candidate-group order == stem_datapath layout == core.stemmer priority
GROUP_DICTS = ("tri", "quad", "tri", "tri", "bi")
GROUP_TAGS = (
    pyref.SRC_TRI,
    pyref.SRC_QUAD,
    pyref.SRC_RESTORED,
    pyref.SRC_DEINFIX_TRI,
    pyref.SRC_DEINFIX_BI,
)
# VMEM residency budget for the three dictionaries combined (int32 words).
# Beyond this, residency="auto" switches to the streamed Compare path
# (minor grid axis over dictionary tiles, DESIGN.md §5.3).
MAX_RESIDENT_KEYS = 1 << 16
RESIDENCIES = ("resident", "streamed", "auto")
MAX_NUM_BUFFERS = 4
_KEY_NOWHERE = jnp.iinfo(jnp.int32).min  # lands in no tile: below every min
# Scalar-prefetch budget for the streamed tile-visit table, in int32
# entries (the table is [batch_tiles, n_dict_tiles]). A megabatch whose
# table would exceed this is chunked along the batch axis into several
# pallas_calls, each with a within-budget table — so grid-over-queue
# megabatches can grow without outgrowing SMEM (the PR 5 open edge).
# 16K entries = 64 KB of scalar memory.
VISIT_SMEM_BUDGET = 1 << 14


def _loaded_keys(roots, infix: bool) -> int:
    """Keys the Compare sweep actually loads: bi only feeds the deinfix
    group, so infix=False never touches it."""
    dicts = (roots.tri, roots.quad) + ((roots.bi,) if infix else ())
    return sum(int(d.shape[0]) for d in dicts)


def choose_residency(roots, residency: str = "auto", *,
                     infix: bool = True) -> str:
    """Resolve residency="auto" against the VMEM budget: keep the packed
    dictionaries resident while they fit, stream tiles once they don't.

    Only the tables the sweep loads count toward the budget: with
    infix=False the bi dictionary never ships to VMEM, so it must not
    force a dictionary that otherwise fits onto the streamed path.
    """
    if residency not in RESIDENCIES:
        raise ValueError(f"unknown residency: {residency!r} (want one of"
                         f" {RESIDENCIES})")
    if residency != "auto":
        return residency
    return ("streamed" if _loaded_keys(roots, infix) > MAX_RESIDENT_KEYS
            else "resident")


def _bank_hit(flat_dict: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """All-pairs comparator bank: keys[bb,6] vs flat_dict[Rp] -> bool[bb,6]."""
    return (keys[..., None] == flat_dict[None, None, :]).any(-1)


def _priority_select(keys, hits_i, root_ref, src_ref, *, n_groups: int):
    """Stage 5b: first hit in VHDL candidate order -> (root, source) tiles.

    One-hot of the first True per row — cumsum==1 on a hit slot — so the
    winning key/tag fall out of a masked sum, gather-free.
    """
    is_first = hits_i * (jnp.cumsum(hits_i, axis=1) == 1)
    chosen = (keys * is_first).sum(axis=1)             # 0 when no hit
    # per-group tag weights are static python ints (no captured constants)
    grp_first = is_first.reshape(-1, n_groups, N_CAND).sum(axis=2)
    source = sum(int(GROUP_TAGS[g]) * grp_first[:, g] for g in range(n_groups))
    root_ref[...] = jnp.stack(
        [(chosen >> 18) & 63, (chosen >> 12) & 63,
         (chosen >> 6) & 63, chosen & 63], axis=1)
    src_ref[...] = source[:, None]


def _candidates(w, n_groups: int):
    """Stages 1-4 on one word tile -> (keys[bb, n_slots], valid[bb, n_slots])."""
    key_cols, val_cols = sdp.candidate_columns(w)
    n_slots = n_groups * N_CAND
    keys = jnp.stack(key_cols[:n_slots], axis=1)
    valid = jnp.stack(val_cols[:n_slots], axis=1) > 0
    return keys, valid


def _resident_hits(keys, valid, dicts, *, n_groups: int, match: str):
    """Stage 5a against VMEM-resident dictionaries -> bool[bb, n_slots]."""
    hit_cols = []
    for g in range(n_groups):
        kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
        d = dicts[GROUP_DICTS[g]]
        hit_cols.append(sm.bsearch_hit(d, kg) if match == "bsearch"
                        else _bank_hit(d, kg))
    return jnp.concatenate(hit_cols, axis=1) & valid


def _fused_kernel(words_ref, tri_ref, quad_ref, bi_ref, root_ref, src_ref,
                  *, n_groups: int, match: str):
    keys, valid = _candidates(words_ref[...], n_groups)  # stages 1-4
    dicts = {"tri": tri_ref[...].reshape(-1),
             "quad": quad_ref[...].reshape(-1),
             "bi": bi_ref[...].reshape(-1)}
    # ---- stage 5a: Compare — per-group match against the resident dict ---
    hits = _resident_hits(keys, valid, dicts, n_groups=n_groups, match=match)
    # ---- stage 5b ----
    _priority_select(keys, hits.astype(jnp.int32), root_ref, src_ref,
                     n_groups=n_groups)


def _dict_slots(name: str, n_groups: int) -> list:
    """Candidate-slot columns fed by dictionary ``name`` (static)."""
    return [g * N_CAND + c for g in range(n_groups)
            if GROUP_DICTS[g] == name for c in range(N_CAND)]


def _visit_tables(keys, valid, tiles: sm.DictTileSet, *, n_groups: int,
                  block_b: int, skip_index: bool):
    """The tile-skipping pre-pass: per-batch-tile dictionary tile-visit
    index from the candidate keys and the sorted tile boundary tables.

    For every batch tile and every dictionary the live candidate keys'
    [min, max] range intersected with the tiles' sorted [mins, maxs]
    boundaries bounds which tiles can hold a hit; because the tiles
    partition a sorted dictionary, each key in fact lands in at most ONE
    tile — `searchsorted(mins, key) - 1`, kept only when the key also
    falls under that tile's max — so the mask marks exactly the landing
    tiles (a strict refinement of the range intersection). A hit requires
    key ∈ dictionary, which implies the key lands in its tile, so
    sweeping only marked tiles is bit-identical to the full sweep.

    keys int32[bp, n_slots], valid bool[bp, n_slots] (stages 1-4 output
    for the padded batch) ->

      n_visits  int32[batch_tiles]           live tiles per batch tile
      visit_idx int32[batch_tiles, n_tiles]  global tile ids, the
                n_visits live ones packed to the front in ascending
                order (pad entries are never fetched)

    skip_index=False marks every tile of every swept dictionary (bi is
    still excluded for infix=False) — the full-sweep baseline through
    the same kernel.
    """
    bt = keys.shape[0] // block_b
    tri_t, quad_t, bi_t = tiles.counts
    masks = []
    for name, base, td in (("tri", 0, tri_t), ("quad", tri_t, quad_t),
                           ("bi", tri_t + quad_t, bi_t)):
        slots = _dict_slots(name, n_groups)
        if not slots:                # bi with infix=False: never swept
            masks.append(jnp.zeros((bt, td), bool))
            continue
        if not skip_index:           # full sweep: every tile of the dict
            masks.append(jnp.ones((bt, td), bool))
            continue
        mins = tiles.mins[base:base + td]
        maxs = tiles.maxs[base:base + td]
        k = jnp.where(valid[:, slots], keys[:, slots], _KEY_NOWHERE)
        k = k.reshape(bt, -1)        # [bt, block_b * n_dict_slots]
        t = jnp.clip(jnp.searchsorted(mins, k, side="right") - 1, 0, td - 1)
        lands = (jnp.take(mins, t) <= k) & (k <= jnp.take(maxs, t))
        bi_idx = jnp.broadcast_to(jnp.arange(bt)[:, None], k.shape)
        mask = jnp.zeros((bt, td), bool)
        mask = mask.at[bi_idx.reshape(-1), t.reshape(-1)].max(lands.reshape(-1))
        masks.append(mask)
    mask = jnp.concatenate(masks, axis=1)              # [bt, n_tiles]
    n_visits = mask.sum(axis=1).astype(jnp.int32)
    # stable argsort on ~mask packs the marked tile ids to the front,
    # ascending — the visit order stays the sorted [tri | quad | bi] order
    visit_idx = jnp.argsort(~mask, axis=1, stable=True).astype(jnp.int32)
    return n_visits, visit_idx


def _ladder_sweep(n, vis_at, keys, valid, dict_ref, dict_bufs, hits_sc,
                  dma_sems, *, n_groups: int, match: str, num_buffers: int,
                  dict_block_r: int, tri_tiles: int, quad_tiles: int):
    """Stage 5a over a visit list of HBM dictionary tiles: the rotating
    ``num_buffers``-deep make_async_copy ladder, OR-accumulating hits
    into ``hits_sc``; returns the final hit mask int32[bb, n_slots].

    ``vis_at(k)`` resolves visit ``k`` (of ``n``) to a *global tile id*
    — the grid kernel reads its batch tile's scalar-prefetched row, the
    persistent kernel its descriptor's. The copy for visit
    k + num_buffers - 1 is issued before visit k's compare runs, so
    tile DMA overlaps the bsearch/bank compute with a tunable lookahead
    (num_buffers=1 is the no-overlap baseline). Which dictionary a tile
    feeds is a static boundary compare on its global tile id (not the
    loop index — the visit list has holes where tiles were skipped).
    Each tile is internally sorted, so its first/last element still
    gives the fine [min, max] reject below the pre-pass' coarse one.
    """
    hits_sc[...] = jnp.zeros_like(hits_sc)

    def tile_dma(k, slot):
        t = vis_at(k)
        return pltpu.make_async_copy(
            dict_ref.at[pl.ds(t * dict_block_r, dict_block_r), :],
            dict_bufs.at[slot], dma_sems.at[slot])

    for s in range(num_buffers - 1):               # warm the ladder
        @pl.when(s < n)
        def _start(s=s):
            tile_dma(s, s).start()

    def visit(k, carry):
        look = k + num_buffers - 1                 # ladder lookahead
        @pl.when(look < n)
        def _fetch_ahead():
            tile_dma(look, jax.lax.rem(look, num_buffers)).start()
        slot = jax.lax.rem(k, num_buffers)
        tile_dma(k, slot).wait()
        tile_id = vis_at(k)
        tile = dict_bufs[slot].reshape(-1)         # (dict_block_r * LANE,)

        # which dictionary holds this tile? static boundaries on tile_id
        dict_active = {
            "tri": tile_id < tri_tiles,
            "quad": (tile_id >= tri_tiles) & (tile_id < tri_tiles + quad_tiles),
            "bi": tile_id >= tri_tiles + quad_tiles}
        slot_active = jnp.concatenate(
            [jnp.broadcast_to(dict_active[GROUP_DICTS[g]], (N_CAND,))
             for g in range(n_groups)])            # (n_slots,)

        # fine tile-range reject: tiles are internally sorted
        in_range = ((keys >= tile[0]) & (keys <= tile[-1])
                    & valid & slot_active[None, :])

        @pl.when(in_range.any())
        def _compare():                            # stage 5a on this tile
            hit_cols = []
            for g in range(n_groups):
                kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
                hit = (sm.bsearch_hit(tile, kg) if match == "bsearch"
                       else _bank_hit(tile, kg))
                hit_cols.append(hit & dict_active[GROUP_DICTS[g]])
            hits = jnp.concatenate(hit_cols, axis=1) & valid
            hits_sc[...] |= hits.astype(jnp.int32)
        return carry

    jax.lax.fori_loop(0, n, visit, 0)
    return hits_sc[...]


def _fused_pipeline_kernel(nvis_ref, vis_ref, words_ref, dict_ref,
                           root_ref, src_ref, dict_bufs, hits_sc, dma_sems,
                           *, n_groups: int, match: str, num_buffers: int,
                           dict_block_r: int, tri_tiles: int,
                           quad_tiles: int):
    """Streamed Compare: grid (batch_tiles,), explicit DMA ladder inside.

    The dictionary stream stays in HBM (memory_space=ANY); the kernel
    walks this batch tile's visit list (scalar-prefetched ``vis_ref``,
    ``nvis_ref[i]`` entries) through :func:`_ladder_sweep`.
    """
    i = pl.program_id(0)
    keys, valid = _candidates(words_ref[...], n_groups)  # stages 1-4
    hits = _ladder_sweep(
        nvis_ref[i], lambda k: vis_ref[i, k], keys, valid, dict_ref,
        dict_bufs, hits_sc, dma_sems, n_groups=n_groups, match=match,
        num_buffers=num_buffers, dict_block_r=dict_block_r,
        tri_tiles=tri_tiles, quad_tiles=quad_tiles)
    _priority_select(keys, hits, root_ref, src_ref,
                     n_groups=n_groups)            # stage 5b


def _persistent_io(desc_ref, d, words_hbm, words_vm, io_sems, block_b):
    """Pull descriptor ``d``'s word tile from HBM into VMEM; returns its
    row offset (descriptor field 0, not the loop index — the ring is
    addressed through its metadata, so tiles can live anywhere in the
    queue buffer)."""
    off = desc_ref[d, 0]
    cp = pltpu.make_async_copy(words_hbm.at[pl.ds(off, block_b), :],
                               words_vm, io_sems.at[0])
    cp.start()
    cp.wait()
    return off


def _persistent_retire(d, off, desc_ref, root_vm, src_vm, root_hbm, src_hbm,
                       flags_ref, io_sems, block_b):
    """Push descriptor ``d``'s finished (root, source) tiles back to HBM
    and mark its completion flag: 1 + the descriptor's version slot, so
    the host-side retire can assert every tile completed under the dict
    version pinned at dispatch (0 = never processed)."""
    cp_r = pltpu.make_async_copy(
        root_vm, root_hbm.at[pl.ds(off, block_b), :], io_sems.at[1])
    cp_s = pltpu.make_async_copy(
        src_vm, src_hbm.at[pl.ds(off, block_b), :], io_sems.at[2])
    cp_r.start()
    cp_s.start()
    cp_r.wait()
    cp_s.wait()
    flags_ref[d] = 1 + desc_ref[d, 2]


def _persistent_streamed_kernel(desc_ref, vis_ref, words_hbm, dict_ref,
                                root_hbm, src_hbm, flags_ref, words_vm,
                                root_vm, src_vm, dict_bufs, hits_sc,
                                dma_sems, io_sems, *, n_groups: int,
                                match: str, num_buffers: int,
                                dict_block_r: int, tri_tiles: int,
                                quad_tiles: int, block_b: int, n_desc: int):
    """The persistent serving kernel, streamed Compare: ONE launch
    (grid=(1,)) fori_loops over a scalar-prefetched work-descriptor ring
    instead of paying one grid step — or worse, one ``pallas_call`` — per
    batch tile.

    Each descriptor is SMEM metadata ``(row offset, n_visits, version
    slot)``; its word tile is DMA'd from the HBM queue buffer, stages
    1-4 run in VMEM, stage 5a reuses the exact :func:`_ladder_sweep` DMA
    ladder over the descriptor's visit row, and the (root, source) tiles
    DMA back to HBM outputs. A per-descriptor completion flag
    (``1 + version slot``) lands in an SMEM output the host polls — the
    retire side of the serving ring keeps its non-blocking ``is_ready``
    contract unchanged.
    """
    def tile(d, carry):
        off = _persistent_io(desc_ref, d, words_hbm, words_vm, io_sems,
                             block_b)
        keys, valid = _candidates(words_vm[...], n_groups)   # stages 1-4
        hits = _ladder_sweep(                                # stage 5a
            desc_ref[d, 1], lambda k: vis_ref[d, k], keys, valid, dict_ref,
            dict_bufs, hits_sc, dma_sems, n_groups=n_groups, match=match,
            num_buffers=num_buffers, dict_block_r=dict_block_r,
            tri_tiles=tri_tiles, quad_tiles=quad_tiles)
        _priority_select(keys, hits, root_vm, src_vm,        # stage 5b
                         n_groups=n_groups)
        _persistent_retire(d, off, desc_ref, root_vm, src_vm, root_hbm,
                           src_hbm, flags_ref, io_sems, block_b)
        return carry

    jax.lax.fori_loop(0, n_desc, tile, 0)


def _persistent_resident_kernel(desc_ref, words_hbm, tri_ref, quad_ref,
                                bi_ref, root_hbm, src_hbm, flags_ref,
                                words_vm, root_vm, src_vm, io_sems, *,
                                n_groups: int, match: str, block_b: int,
                                n_desc: int):
    """Persistent serving kernel, resident Compare: the packed
    dictionaries sit in VMEM for the whole launch while the descriptor
    loop streams word tiles through; same descriptor/flag contract as
    the streamed variant."""
    dicts = {"tri": tri_ref[...].reshape(-1),
             "quad": quad_ref[...].reshape(-1),
             "bi": bi_ref[...].reshape(-1)}

    def tile(d, carry):
        off = _persistent_io(desc_ref, d, words_hbm, words_vm, io_sems,
                             block_b)
        keys, valid = _candidates(words_vm[...], n_groups)   # stages 1-4
        hits = _resident_hits(keys, valid, dicts, n_groups=n_groups,
                              match=match)                   # stage 5a
        _priority_select(keys, hits.astype(jnp.int32), root_vm, src_vm,
                         n_groups=n_groups)                  # stage 5b
        _persistent_retire(d, off, desc_ref, root_vm, src_vm, root_hbm,
                           src_hbm, flags_ref, io_sems, block_b)
        return carry

    jax.lax.fori_loop(0, n_desc, tile, 0)


@functools.partial(
    jax.jit, static_argnames=("infix", "match", "block_b", "residency",
                              "dict_block_r", "num_buffers", "skip_index",
                              "persistent", "visit_budget", "interpret"))
def stem_fused_pallas(
    words: jnp.ndarray,
    roots,
    *,
    infix: bool = True,
    match: str = "bsearch",
    block_b: int = 256,
    residency: str = "auto",
    dict_block_r: int = 8,
    num_buffers: int = 2,
    skip_index: bool = True,
    persistent: bool = False,
    version_slot=0,
    visit_budget: int | None = None,
    interpret: bool = False,
):
    """words int32[B,16] + RootDictArrays -> (root int32[B,4], source int32[B]).

    The grid's batch axis spans every ``block_b`` tile of the batch, so
    one launch retires an arbitrarily deep queue megabatch; ``residency``
    picks the dictionary layout (DESIGN.md §5.3):

      "resident"  grid = batch tiles only; the packed dictionaries ride
                  along as constant-index-map VMEM blocks. Raises past
                  MAX_RESIDENT_KEYS (it would thrash VMEM).
      "streamed"  grid = batch tiles; per batch tile the kernel sweeps a
                  scalar-prefetched visit list of (dict_block_r x 128)
                  dictionary tiles, DMA'd from HBM through a
                  ``num_buffers``-deep explicit ladder; with
                  ``skip_index`` only the tiles a candidate key can land
                  in are visited at all. The visit table costs
                  ``batch_tiles x n_tiles`` int32 of scalar-prefetch
                  (SMEM) space; megabatches whose table would exceed
                  ``visit_budget`` (default VISIT_SMEM_BUDGET) are
                  chunked along the batch axis into several
                  pallas_calls, each with a within-budget table.
      "auto"      resident while the dictionaries fit, streamed beyond.

    ``persistent=True`` selects the persistent serving kernel: ONE
    launch (grid=(1,)) whose body fori_loops over a device-side
    work-descriptor ring — scalar-prefetched ``(row offset, n_visits,
    version slot)`` tuples in SMEM — DMA-ing each word tile in, running
    the full five-stage pipeline (the streamed variant reuses the exact
    DMA ladder), and DMA-ing (root, source) back out. The return value
    grows a third element: per-descriptor completion ``flags``
    int32[batch_tiles], ``1 + version_slot`` once a tile retires (0 =
    never processed), which the serving ring polls at retire.
    ``version_slot`` (traced, so hot swaps never re-trace) stamps the
    flags with the dictionary version pinned at dispatch.

    ``num_buffers`` (1..4; streamed only) sets the DMA lookahead depth —
    2 double-buffers, 1 is the no-overlap baseline. ``skip_index=False``
    (streamed only) disables tile skipping and sweeps every tile of the
    loaded dictionaries through the same ladder.

    Bit-identical to ``core.stemmer.extract_roots`` (and pyref) in every
    (residency, match, num_buffers, skip_index, persistent) combination.

    ``roots`` also accepts a ``core.stemmer.ResolvedRootDict`` handle:
    its pinned residency replaces the residency argument, and a handle
    carrying a prebuilt ``stem_match.DictTileSet`` of matching
    dict_block_r skips the per-call pad/concat of the tile stream
    (serving resolves both once at dictionary-publish time, so a hot
    swap whose arrays keep their shapes replays the cached trace).
    """
    if match not in ("bank", "bsearch"):
        raise ValueError(f"unknown in-kernel match strategy: {match}")
    if not 1 <= num_buffers <= MAX_NUM_BUFFERS:
        raise ValueError(f"num_buffers must be in 1..{MAX_NUM_BUFFERS},"
                         f" got {num_buffers}")
    n_groups = 5 if infix else 2
    roots, residency, tiles = core_stemmer.unwrap_dict(roots, residency)
    residency = choose_residency(roots, residency, infix=infix)

    loaded = _loaded_keys(roots, infix)
    if residency == "resident" and loaded > MAX_RESIDENT_KEYS:
        raise ValueError(
            f"dictionaries too large for VMEM residency ({loaded} keys >"
            f" {MAX_RESIDENT_KEYS}); use residency='streamed' or 'auto'"
            " (DESIGN.md §5.3)")

    b = words.shape[0]
    if b == 0:  # degenerate batch: nothing to launch
        empty = (jnp.zeros((0, 4), jnp.int32), jnp.zeros((0,), jnp.int32))
        return empty + (jnp.zeros((0,), jnp.int32),) if persistent else empty
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    bp = wp.shape[0]
    bt = bp // block_b

    word_spec = pl.BlockSpec((block_b, ab.MAXLEN), lambda i, *a: (i, 0))
    out_specs = [pl.BlockSpec((block_b, 4), lambda i, *a: (i, 0)),
                 pl.BlockSpec((block_b, 1), lambda i, *a: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((bp, 4), jnp.int32),
                 jax.ShapeDtypeStruct((bp, 1), jnp.int32)]

    if residency == "resident":
        prep = sm.pad_dict_sorted if match == "bsearch" else sm.pad_dict_lanes
        # infix=False never reads the bi dict: ship a one-lane placeholder
        # so the unused table doesn't occupy VMEM (see choose_residency)
        bi = roots.bi if infix else jnp.full((1,), sm.DICT_PAD, jnp.int32)
        tri2, quad2, bi2 = prep(roots.tri), prep(roots.quad), prep(bi)
        dict_spec = lambda d: pl.BlockSpec(d.shape, lambda i, *a: (0, 0))
        if persistent:
            return _persistent_resident_call(
                wp, (tri2, quad2, bi2), dict_spec, version_slot, b=b,
                block_b=block_b, n_groups=n_groups, match=match,
                interpret=interpret)
        root, source = pl.pallas_call(
            functools.partial(_fused_kernel, n_groups=n_groups, match=match),
            grid=(bt,),
            in_specs=[word_spec,
                      dict_spec(tri2), dict_spec(quad2), dict_spec(bi2)],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(wp, tri2, quad2, bi2)
        return root[:b], source[:b, 0]

    # ---- streamed: scalar-prefetched visit index + explicit DMA ladder ---
    if tiles is None or tiles.dict_block_r != dict_block_r:
        tiles = sm.build_dict_tiles(roots.tri, roots.quad, roots.bi,
                                    dict_block_r)
    tri_tiles, quad_tiles, _ = tiles.counts
    n_slots = n_groups * N_CAND

    # pre-pass (stages 1-4 in jnp, the same candidate_columns body the
    # kernel runs): which dictionary tiles can this batch tile hit?
    kc, vc = sdp.candidate_columns(wp)
    n_visits, visit_idx = _visit_tables(
        jnp.stack(kc[:n_slots], axis=1), jnp.stack(vc[:n_slots], axis=1) > 0,
        tiles, n_groups=n_groups, block_b=block_b, skip_index=skip_index)

    # chunk the scalar-prefetch table along the batch axis: each chunk's
    # [chunk_bt, n_tiles] table stays inside the SMEM budget (megabatches
    # otherwise grow it without bound — the PR 5 open edge)
    budget = VISIT_SMEM_BUDGET if visit_budget is None else visit_budget
    max_bt = max(1, budget // tiles.n_tiles)
    kern_args = dict(n_groups=n_groups, match=match, num_buffers=num_buffers,
                     dict_block_r=dict_block_r, tri_tiles=tri_tiles,
                     quad_tiles=quad_tiles)
    roots_out, srcs_out, flags_out = [], [], []
    for c0 in range(0, bt, max_bt):
        c1 = min(bt, c0 + max_bt)
        cw = slice(c0 * block_b, c1 * block_b)
        if persistent:
            r, s, f = _persistent_streamed_call(
                wp[cw], tiles.stream, n_visits[c0:c1], visit_idx[c0:c1],
                version_slot, block_b=block_b, n_slots=n_slots,
                interpret=interpret, **kern_args)
            flags_out.append(f)
        else:
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,      # (n_visits, visit_idx) -> SMEM
                grid=(c1 - c0,),
                in_specs=[word_spec,
                          pl.BlockSpec(memory_space=pltpu.ANY)],  # dict: HBM
                out_specs=out_specs,
                scratch_shapes=[
                    pltpu.VMEM((num_buffers, dict_block_r, sm.LANE),
                               jnp.int32),
                    pltpu.VMEM((block_b, n_slots), jnp.int32),
                    pltpu.SemaphoreType.DMA((num_buffers,)),
                ],
            )
            r, s = pl.pallas_call(
                functools.partial(_fused_pipeline_kernel, **kern_args),
                grid_spec=grid_spec,
                out_shape=[
                    jax.ShapeDtypeStruct(((c1 - c0) * block_b, 4), jnp.int32),
                    jax.ShapeDtypeStruct(((c1 - c0) * block_b, 1), jnp.int32),
                ],
                interpret=interpret,
            )(n_visits[c0:c1], visit_idx[c0:c1], wp[cw], tiles.stream)
        roots_out.append(r)
        srcs_out.append(s)
    root = roots_out[0] if len(roots_out) == 1 else jnp.concatenate(roots_out)
    source = srcs_out[0] if len(srcs_out) == 1 else jnp.concatenate(srcs_out)
    if persistent:
        flags = (flags_out[0] if len(flags_out) == 1
                 else jnp.concatenate(flags_out))
        return root[:b], source[:b, 0], flags
    return root[:b], source[:b, 0]


def _descriptors(bt: int, block_b: int, n_visits, version_slot):
    """Pack the work-descriptor ring: int32[bt, 3] of (row offset,
    n_visits, version slot) per tile, delivered via scalar prefetch."""
    ver = jnp.broadcast_to(jnp.asarray(version_slot, jnp.int32), (bt,))
    offs = jnp.arange(bt, dtype=jnp.int32) * block_b
    return jnp.stack([offs, n_visits.astype(jnp.int32), ver], axis=1)


def _persistent_resident_call(wp, dicts, dict_spec, version_slot, *, b: int,
                              block_b: int, n_groups: int, match: str,
                              interpret: bool):
    bp = wp.shape[0]
    bt = bp // block_b
    desc = _descriptors(bt, block_b, jnp.zeros(bt, jnp.int32), version_slot)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,              # descriptor ring -> SMEM
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] + [
            dict_spec(d) for d in dicts],
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                   pl.BlockSpec(memory_space=pltpu.ANY),
                   pl.BlockSpec(memory_space=pltpu.SMEM)],
        scratch_shapes=[
            pltpu.VMEM((block_b, ab.MAXLEN), jnp.int32),
            pltpu.VMEM((block_b, 4), jnp.int32),
            pltpu.VMEM((block_b, 1), jnp.int32),
            pltpu.SemaphoreType.DMA((3,)),
        ],
    )
    root, source, flags = pl.pallas_call(
        functools.partial(_persistent_resident_kernel, n_groups=n_groups,
                          match=match, block_b=block_b, n_desc=bt),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bp, 4), jnp.int32),
                   jax.ShapeDtypeStruct((bp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((bt,), jnp.int32)],
        interpret=interpret,
    )(desc, wp, *dicts)
    return root[:b], source[:b, 0], flags


def _persistent_streamed_call(wp, stream, n_visits, visit_idx, version_slot,
                              *, block_b: int, n_slots: int, n_groups: int,
                              match: str, num_buffers: int, dict_block_r: int,
                              tri_tiles: int, quad_tiles: int,
                              interpret: bool):
    bp = wp.shape[0]
    bt = bp // block_b
    desc = _descriptors(bt, block_b, n_visits, version_slot)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # (descriptors, visit rows)
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),   # word queue: HBM
                  pl.BlockSpec(memory_space=pltpu.ANY)],  # dict: HBM
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                   pl.BlockSpec(memory_space=pltpu.ANY),
                   pl.BlockSpec(memory_space=pltpu.SMEM)],
        scratch_shapes=[
            pltpu.VMEM((block_b, ab.MAXLEN), jnp.int32),
            pltpu.VMEM((block_b, 4), jnp.int32),
            pltpu.VMEM((block_b, 1), jnp.int32),
            pltpu.VMEM((num_buffers, dict_block_r, sm.LANE), jnp.int32),
            pltpu.VMEM((block_b, n_slots), jnp.int32),
            pltpu.SemaphoreType.DMA((num_buffers,)),
            pltpu.SemaphoreType.DMA((3,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_persistent_streamed_kernel, n_groups=n_groups,
                          match=match, num_buffers=num_buffers,
                          dict_block_r=dict_block_r, tri_tiles=tri_tiles,
                          quad_tiles=quad_tiles, block_b=block_b, n_desc=bt),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bp, 4), jnp.int32),
                   jax.ShapeDtypeStruct((bp, 1), jnp.int32),
                   jax.ShapeDtypeStruct((bt,), jnp.int32)],
        interpret=interpret,
    )(desc, visit_idx, wp, stream)


def salvage_descriptor_rows(flags, version_slot: int, block_b: int) -> int:
    """Host-side watchdog helper: how many leading rows of an abandoned
    persistent launch its completion flags prove retired.

    Descriptors retire in ring order (the kernel's fori_loop), so a
    wedge leaves exactly a *prefix* of flags equal to ``1 +
    version_slot`` — anything after the first unretired descriptor is
    unproven even if its flag looks set (the flag write races the
    wedge). Returns ``block_b * k`` for the longest such prefix: the
    rows the watchdog may scatter; the rest re-dispatches down the
    megabatch path.
    """
    f = np.asarray(flags)
    good = f == 1 + version_slot
    k = int(f.size if good.all() else np.argmin(good))
    return k * block_b


def dict_tile_count(roots, dict_block_r: int) -> int:
    """Tiles in the streamed `[tri | quad | bi]` stream (mirrors
    stem_match.pad_dict_tiles: every table pads to >= one full tile)."""
    per = dict_block_r * sm.LANE
    return sum(max(1, -(-int(t.shape[0]) // per))
               for t in (roots.tri, roots.quad, roots.bi))


def planned_launches(n_words: int, roots, *, infix: bool = True,
                     block_b: int = 256, residency: str = "auto",
                     dict_block_r: int = 8, persistent: bool = False,
                     visit_budget: int | None = None) -> int:
    """``pallas_call`` dispatches one :func:`stem_fused_pallas` invocation
    issues for this configuration — the launch accounting behind
    ``ops.dispatch_count()`` and the ``launch_overhead`` benchmark.

    Resident launches are always 1; streamed (and persistent-streamed)
    launches are ceil(batch_tiles / chunk) where chunk is the largest
    batch-tile count whose scalar-prefetch visit table fits the SMEM
    budget.
    """
    roots, residency, tiles = core_stemmer.unwrap_dict(roots, residency)
    residency = choose_residency(roots, residency, infix=infix)
    if n_words == 0:
        return 0
    if residency == "resident":
        return 1
    if tiles is not None and tiles.dict_block_r == dict_block_r:
        n_tiles = tiles.n_tiles
    else:
        n_tiles = dict_tile_count(roots, dict_block_r)
    budget = VISIT_SMEM_BUDGET if visit_budget is None else visit_budget
    max_bt = max(1, budget // n_tiles)
    bt = -(-n_words // block_b)
    return -(-bt // max_bt)


def tile_visit_stats(words, roots, *, infix: bool = True, block_b: int = 256,
                     dict_block_r: int = 8, skip_index: bool = True) -> dict:
    """Run only the tile-skipping pre-pass and report visit counts.

    Returns ``{"visited": total tile visits across batch tiles,
    "full_sweep": batch_tiles * live dictionary tiles (what
    skip_index=False visits), "batch_tiles", "dict_tiles"}`` — the
    numbers the ``dict_stream_pipeline`` benchmark rows record so the
    skip index's coverage is tracked next to its timings.
    """
    roots, _, tiles = core_stemmer.unwrap_dict(roots, "auto")
    if tiles is None or tiles.dict_block_r != dict_block_r:
        tiles = sm.build_dict_tiles(roots.tri, roots.quad, roots.bi,
                                    dict_block_r)
    n_groups = 5 if infix else 2
    b = words.shape[0]
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    n_slots = n_groups * N_CAND
    kc, vc = sdp.candidate_columns(wp)
    n_visits, _ = _visit_tables(
        jnp.stack(kc[:n_slots], axis=1), jnp.stack(vc[:n_slots], axis=1) > 0,
        tiles, n_groups=n_groups, block_b=block_b, skip_index=skip_index)
    bt = wp.shape[0] // block_b
    tri_t, quad_t, bi_t = tiles.counts
    live = tri_t + quad_t + (bi_t if infix else 0)
    return {"visited": int(jnp.sum(n_visits)), "full_sweep": bt * live,
            "batch_tiles": bt, "dict_tiles": live}
