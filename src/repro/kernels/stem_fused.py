"""Pallas TPU megakernel: the whole stemmer (stages 1-5) in ONE launch.

The paper's pipelined FPGA processor earns its speedup by keeping every
stage on-chip: values never leave the datapath between Check / Produce /
Generate / Filter / Compare. The previous "fused" TPU path was six
separate ``pallas_call`` launches (1 datapath + 5 dictionary matches)
that round-tripped keys, validity flags and hit masks through HBM. This
kernel is the faithful analogue of the paper's architecture: a word tile
enters VMEM once and ``(root, source)`` comes out — candidates, validity
flags and hit masks live only in registers/VMEM.

Layout (see DESIGN.md §5):
  - the three packed root dictionaries (tri/quad/bi, int32 keys; ~2K
    entries total for realistic dictionaries) ride along as
    VMEM-resident blocks with a constant index map, so the pipeline
    fetches them once and revisits them for every batch tile;
  - stages 1-4 are the shared :func:`stem_datapath.candidate_columns`
    datapath (unrolled AND/OR masking networks, truncation grid, infix
    transforms, 24-bit key packing);
  - stage 5 (Compare) supports two in-kernel strategies:
      match="bank"     all-pairs equality against the dictionary tile —
                       the paper's comparator banks (O(R) per candidate);
      match="bsearch"  unrolled branchless binary search over the sorted
                       dictionary — the paper's §7 proposed tree search
                       (ceil(log2 R) static steps, O(log R) per
                       candidate); see stem_match.bsearch_hit;
  - the priority select (first hit in VHDL candidate order) is a
    cumulative-sum one-hot reduction, so no gather is needed on the
    output side.

Dictionaries large enough to pressure VMEM (>~64K keys) take the
*streamed* Compare path (DESIGN.md §5.3): a second, minor grid axis
iterates (tile_rows x 128) dictionary tiles through VMEM while the word
tile, its candidate keys/validity and an OR-accumulating hit mask persist
in VMEM scratch across the sweep — the stem_match._match_kernel revisit
pattern lifted into the megakernel. The datapath (stages 1-4) runs only
on the first revisit; the priority select only on the last. Each tile
carries a [min, max] range reject, so for sorted dictionaries most tiles
cost one predicated compare. `residency="resident"|"streamed"|"auto"`
selects the layout; "auto" streams once the packed dictionaries exceed
MAX_RESIDENT_KEYS.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import alphabet as ab
from repro.core import pyref
from repro.core import stemmer as core_stemmer
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_match as sm

N_CAND = 6
# candidate-group order == stem_datapath layout == core.stemmer priority
GROUP_DICTS = ("tri", "quad", "tri", "tri", "bi")
GROUP_TAGS = (
    pyref.SRC_TRI,
    pyref.SRC_QUAD,
    pyref.SRC_RESTORED,
    pyref.SRC_DEINFIX_TRI,
    pyref.SRC_DEINFIX_BI,
)
# VMEM residency budget for the three dictionaries combined (int32 words).
# Beyond this, residency="auto" switches to the streamed Compare path
# (minor grid axis over dictionary tiles, DESIGN.md §5.3).
MAX_RESIDENT_KEYS = 1 << 16
RESIDENCIES = ("resident", "streamed", "auto")


def choose_residency(roots, residency: str = "auto") -> str:
    """Resolve residency="auto" against the VMEM budget: keep the packed
    dictionaries resident while they fit, stream tiles once they don't."""
    if residency not in RESIDENCIES:
        raise ValueError(f"unknown residency: {residency!r} (want one of"
                         f" {RESIDENCIES})")
    if residency != "auto":
        return residency
    total = sum(int(d.shape[0]) for d in (roots.tri, roots.quad, roots.bi))
    return "streamed" if total > MAX_RESIDENT_KEYS else "resident"


def _bank_hit(flat_dict: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """All-pairs comparator bank: keys[bb,6] vs flat_dict[Rp] -> bool[bb,6]."""
    return (keys[..., None] == flat_dict[None, None, :]).any(-1)


def _priority_select(keys, hits_i, root_ref, src_ref, *, n_groups: int):
    """Stage 5b: first hit in VHDL candidate order -> (root, source) tiles.

    One-hot of the first True per row — cumsum==1 on a hit slot — so the
    winning key/tag fall out of a masked sum, gather-free.
    """
    is_first = hits_i * (jnp.cumsum(hits_i, axis=1) == 1)
    chosen = (keys * is_first).sum(axis=1)             # 0 when no hit
    # per-group tag weights are static python ints (no captured constants)
    grp_first = is_first.reshape(-1, n_groups, N_CAND).sum(axis=2)
    source = sum(int(GROUP_TAGS[g]) * grp_first[:, g] for g in range(n_groups))
    root_ref[...] = jnp.stack(
        [(chosen >> 18) & 63, (chosen >> 12) & 63,
         (chosen >> 6) & 63, chosen & 63], axis=1)
    src_ref[...] = source[:, None]


def _fused_kernel(words_ref, tri_ref, quad_ref, bi_ref, root_ref, src_ref,
                  *, n_groups: int, match: str):
    w = words_ref[...]                             # (bb, 16) int32
    key_cols, val_cols = sdp.candidate_columns(w)  # stages 1-4, 30 columns
    n_slots = n_groups * N_CAND
    keys = jnp.stack(key_cols[:n_slots], axis=1)   # (bb, n_slots)
    valid = jnp.stack(val_cols[:n_slots], axis=1) > 0

    dicts = {"tri": tri_ref[...].reshape(-1),
             "quad": quad_ref[...].reshape(-1),
             "bi": bi_ref[...].reshape(-1)}

    # ---- stage 5a: Compare — per-group match against the resident dict ---
    hit_cols = []
    for g in range(n_groups):
        kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
        d = dicts[GROUP_DICTS[g]]
        hit_cols.append(sm.bsearch_hit(d, kg) if match == "bsearch"
                        else _bank_hit(d, kg))
    hits = jnp.concatenate(hit_cols, axis=1) & valid   # (bb, n_slots)

    # ---- stage 5b ----
    _priority_select(keys, hits.astype(jnp.int32), root_ref, src_ref,
                     n_groups=n_groups)


def _fused_streamed_kernel(words_ref, dict_ref, root_ref, src_ref,
                           keys_sc, valid_sc, hits_sc,
                           *, n_groups: int, match: str,
                           tri_tiles: int, quad_tiles: int):
    """Streamed Compare: grid (batch_tiles, dict_tiles), dict axis minor.

    The word tile's candidate keys/valid flags and the OR-accumulating hit
    mask live in VMEM scratch across the dictionary sweep; the datapath
    runs once per word tile (first revisit), the priority select once
    (last revisit). The concatenated dictionary stream is
    [tri tiles | quad tiles | bi tiles]; which groups a tile feeds is a
    static-boundary comparison on the minor program id. Each tile is
    internally sorted (sentinel padded), so its first/last element gives a
    [min, max] range reject: tiles that cannot contain any live candidate
    key cost one predicated compare and skip the search entirely.
    """
    j = pl.program_id(1)
    n_tiles = pl.num_programs(1)
    n_slots = n_groups * N_CAND

    @pl.when(j == 0)
    def _ingest():                                 # stages 1-4, once per tile
        w = words_ref[...]                         # (bb, 16) int32
        key_cols, val_cols = sdp.candidate_columns(w)
        keys_sc[...] = jnp.stack(key_cols[:n_slots], axis=1)
        valid_sc[...] = jnp.stack(val_cols[:n_slots], axis=1)
        hits_sc[...] = jnp.zeros_like(hits_sc)

    keys = keys_sc[...]                            # (bb, n_slots)
    valid = valid_sc[...] > 0
    tile = dict_ref[...].reshape(-1)               # (tile_rows * LANE,)

    # which dictionary does tile j hold? static boundaries on the minor axis
    dict_active = {"tri": j < tri_tiles,
                   "quad": (j >= tri_tiles) & (j < tri_tiles + quad_tiles),
                   "bi": j >= tri_tiles + quad_tiles}
    slot_active = jnp.concatenate(
        [jnp.broadcast_to(dict_active[GROUP_DICTS[g]], (N_CAND,))
         for g in range(n_groups)])                # (n_slots,)

    # ---- cheap tile-range reject: tiles are internally sorted ------------
    in_range = ((keys >= tile[0]) & (keys <= tile[-1])
                & valid & slot_active[None, :])

    @pl.when(in_range.any())
    def _compare():                                # stage 5a on this tile
        hit_cols = []
        for g in range(n_groups):
            kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
            hit = (sm.bsearch_hit(tile, kg) if match == "bsearch"
                   else _bank_hit(tile, kg))
            hit_cols.append(hit & dict_active[GROUP_DICTS[g]])
        hits = jnp.concatenate(hit_cols, axis=1) & valid
        hits_sc[...] |= hits.astype(jnp.int32)

    @pl.when(j == n_tiles - 1)
    def _select():                                 # stage 5b, once per tile
        _priority_select(keys, hits_sc[...], root_ref, src_ref,
                         n_groups=n_groups)


@functools.partial(
    jax.jit, static_argnames=("infix", "match", "block_b", "residency",
                              "dict_block_r", "interpret"))
def stem_fused_pallas(
    words: jnp.ndarray,
    roots,
    *,
    infix: bool = True,
    match: str = "bsearch",
    block_b: int = 256,
    residency: str = "auto",
    dict_block_r: int = 8,
    interpret: bool = False,
):
    """words int32[B,16] + RootDictArrays -> (root int32[B,4], source int32[B]).

    Single ``pallas_call`` either way; ``residency`` picks the dictionary
    layout (DESIGN.md §5.3):

      "resident"  grid = batch tiles only; the packed dictionaries ride
                  along as constant-index-map VMEM blocks. Raises past
                  MAX_RESIDENT_KEYS (it would thrash VMEM).
      "streamed"  grid = (batch tiles, dict tiles); (dict_block_r x 128)
                  tiles stream through VMEM while keys/valid/hit-mask
                  persist in scratch — unbounded dictionary sizes.
      "auto"      resident while the dictionaries fit, streamed beyond.

    Bit-identical to ``core.stemmer.extract_roots`` (and pyref) in every
    (residency, match) combination.

    ``roots`` also accepts a ``core.stemmer.ResolvedRootDict`` handle:
    its pinned residency replaces the residency argument (serving
    resolves "auto" once at dictionary-publish time, so a hot swap whose
    arrays keep their shapes replays the cached trace).
    """
    if match not in ("bank", "bsearch"):
        raise ValueError(f"unknown in-kernel match strategy: {match}")
    n_groups = 5 if infix else 2
    roots, residency = core_stemmer.unwrap_dict(roots, residency)
    residency = choose_residency(roots, residency)

    total_keys = sum(int(d.shape[0]) for d in (roots.tri, roots.quad, roots.bi))
    if residency == "resident" and total_keys > MAX_RESIDENT_KEYS:
        raise ValueError(
            f"dictionaries too large for VMEM residency ({total_keys} keys >"
            f" {MAX_RESIDENT_KEYS}); use residency='streamed' or 'auto'"
            " (DESIGN.md §5.3)")

    b = words.shape[0]
    if b == 0:  # degenerate batch: nothing to launch
        return (jnp.zeros((0, 4), jnp.int32), jnp.zeros((0,), jnp.int32))
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    bp = wp.shape[0]

    word_spec = pl.BlockSpec((block_b, ab.MAXLEN), lambda i, *j: (i, 0))
    out_specs = [pl.BlockSpec((block_b, 4), lambda i, *j: (i, 0)),
                 pl.BlockSpec((block_b, 1), lambda i, *j: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((bp, 4), jnp.int32),
                 jax.ShapeDtypeStruct((bp, 1), jnp.int32)]

    if residency == "resident":
        prep = sm.pad_dict_sorted if match == "bsearch" else sm.pad_dict_lanes
        tri2, quad2, bi2 = prep(roots.tri), prep(roots.quad), prep(roots.bi)
        dict_spec = lambda d: pl.BlockSpec(d.shape, lambda i: (0, 0))
        root, source = pl.pallas_call(
            functools.partial(_fused_kernel, n_groups=n_groups, match=match),
            grid=(bp // block_b,),
            in_specs=[word_spec,
                      dict_spec(tri2), dict_spec(quad2), dict_spec(bi2)],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(wp, tri2, quad2, bi2)
        return root[:b], source[:b, 0]

    # ---- streamed: minor grid axis sweeps [tri | quad | bi] dict tiles ---
    dicts = [roots.tri, roots.quad] + ([roots.bi] if n_groups == 5 else [])
    tiles = [sm.pad_dict_tiles(d, dict_block_r) for d in dicts]
    counts = [t.shape[0] // dict_block_r for t in tiles]
    tri_tiles, quad_tiles = counts[0], counts[1]
    dict_stream = jnp.concatenate(tiles, axis=0)
    n_slots = n_groups * N_CAND

    root, source = pl.pallas_call(
        functools.partial(_fused_streamed_kernel, n_groups=n_groups,
                          match=match, tri_tiles=tri_tiles,
                          quad_tiles=quad_tiles),
        grid=(bp // block_b, sum(counts)),
        in_specs=[word_spec,
                  pl.BlockSpec((dict_block_r, sm.LANE), lambda i, j: (j, 0))],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block_b, n_slots), jnp.int32),
                        pltpu.VMEM((block_b, n_slots), jnp.int32),
                        pltpu.VMEM((block_b, n_slots), jnp.int32)],
        interpret=interpret,
    )(wp, dict_stream)
    return root[:b], source[:b, 0]
