"""Pallas TPU megakernel: the whole stemmer (stages 1-5) in ONE launch.

The paper's pipelined FPGA processor earns its speedup by keeping every
stage on-chip: values never leave the datapath between Check / Produce /
Generate / Filter / Compare. The previous "fused" TPU path was six
separate ``pallas_call`` launches (1 datapath + 5 dictionary matches)
that round-tripped keys, validity flags and hit masks through HBM. This
kernel is the faithful analogue of the paper's architecture: a word tile
enters VMEM once and ``(root, source)`` comes out — candidates, validity
flags and hit masks live only in registers/VMEM.

Layout (see DESIGN.md §5):
  - the three packed root dictionaries (tri/quad/bi, int32 keys; ~2K
    entries total for realistic dictionaries) ride along as
    VMEM-resident blocks with a constant index map, so the pipeline
    fetches them once and revisits them for every batch tile;
  - stages 1-4 are the shared :func:`stem_datapath.candidate_columns`
    datapath (unrolled AND/OR masking networks, truncation grid, infix
    transforms, 24-bit key packing);
  - stage 5 (Compare) supports two in-kernel strategies:
      match="bank"     all-pairs equality against the dictionary tile —
                       the paper's comparator banks (O(R) per candidate);
      match="bsearch"  unrolled branchless binary search over the sorted
                       dictionary — the paper's §7 proposed tree search
                       (ceil(log2 R) static steps, O(log R) per
                       candidate); see stem_match.bsearch_hit;
  - the priority select (first hit in VHDL candidate order) is a
    cumulative-sum one-hot reduction, so no gather is needed on the
    output side.

Dictionaries large enough to pressure VMEM (>~64K keys) take the
*streamed* Compare path (DESIGN.md §5.3), an explicitly pipelined sweep:

  - a jnp pre-pass (stages 1-4 on the padded batch, shared
    ``candidate_columns`` body) computes where every batch tile's live
    candidate keys land among the sorted `(dict_block_r x 128)`
    dictionary tiles, and emits a per-batch-tile **tile-visit index** —
    only tiles that can contain a hit are visited, not all of them. The
    index and per-tile visit counts reach the kernel through scalar
    prefetch (``pltpu.PrefetchScalarGridSpec``), so tile ids are
    available for DMA issue before the compute touches them.
  - the dictionary stream stays in HBM (``memory_space=ANY``) and the
    kernel drives its own multi-buffered ``pltpu.make_async_copy``
    ladder (``num_buffers`` deep): the DMA for visit k+num_buffers-1 is
    started before visit k's bsearch/bank compare runs, replacing the
    implicit single-stage Pallas pipeline of the previous layout. An
    OR-accumulating hit mask persists in VMEM scratch across the sweep;
    the priority select runs once per batch tile after it.

`residency="resident"|"streamed"|"auto"` selects the layout; "auto"
streams once the packed dictionaries exceed MAX_RESIDENT_KEYS
(counting only the tables the sweep loads: bi is excluded for
infix=False). `skip_index=False` degrades the visit index to the full
sweep — same kernel, every live tile visited — which is the baseline the
`dict_stream_pipeline` benchmark section compares against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import alphabet as ab
from repro.core import pyref
from repro.core import stemmer as core_stemmer
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_match as sm

N_CAND = 6
# candidate-group order == stem_datapath layout == core.stemmer priority
GROUP_DICTS = ("tri", "quad", "tri", "tri", "bi")
GROUP_TAGS = (
    pyref.SRC_TRI,
    pyref.SRC_QUAD,
    pyref.SRC_RESTORED,
    pyref.SRC_DEINFIX_TRI,
    pyref.SRC_DEINFIX_BI,
)
# VMEM residency budget for the three dictionaries combined (int32 words).
# Beyond this, residency="auto" switches to the streamed Compare path
# (minor grid axis over dictionary tiles, DESIGN.md §5.3).
MAX_RESIDENT_KEYS = 1 << 16
RESIDENCIES = ("resident", "streamed", "auto")
MAX_NUM_BUFFERS = 4
_KEY_NOWHERE = jnp.iinfo(jnp.int32).min  # lands in no tile: below every min


def _loaded_keys(roots, infix: bool) -> int:
    """Keys the Compare sweep actually loads: bi only feeds the deinfix
    group, so infix=False never touches it."""
    dicts = (roots.tri, roots.quad) + ((roots.bi,) if infix else ())
    return sum(int(d.shape[0]) for d in dicts)


def choose_residency(roots, residency: str = "auto", *,
                     infix: bool = True) -> str:
    """Resolve residency="auto" against the VMEM budget: keep the packed
    dictionaries resident while they fit, stream tiles once they don't.

    Only the tables the sweep loads count toward the budget: with
    infix=False the bi dictionary never ships to VMEM, so it must not
    force a dictionary that otherwise fits onto the streamed path.
    """
    if residency not in RESIDENCIES:
        raise ValueError(f"unknown residency: {residency!r} (want one of"
                         f" {RESIDENCIES})")
    if residency != "auto":
        return residency
    return ("streamed" if _loaded_keys(roots, infix) > MAX_RESIDENT_KEYS
            else "resident")


def _bank_hit(flat_dict: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """All-pairs comparator bank: keys[bb,6] vs flat_dict[Rp] -> bool[bb,6]."""
    return (keys[..., None] == flat_dict[None, None, :]).any(-1)


def _priority_select(keys, hits_i, root_ref, src_ref, *, n_groups: int):
    """Stage 5b: first hit in VHDL candidate order -> (root, source) tiles.

    One-hot of the first True per row — cumsum==1 on a hit slot — so the
    winning key/tag fall out of a masked sum, gather-free.
    """
    is_first = hits_i * (jnp.cumsum(hits_i, axis=1) == 1)
    chosen = (keys * is_first).sum(axis=1)             # 0 when no hit
    # per-group tag weights are static python ints (no captured constants)
    grp_first = is_first.reshape(-1, n_groups, N_CAND).sum(axis=2)
    source = sum(int(GROUP_TAGS[g]) * grp_first[:, g] for g in range(n_groups))
    root_ref[...] = jnp.stack(
        [(chosen >> 18) & 63, (chosen >> 12) & 63,
         (chosen >> 6) & 63, chosen & 63], axis=1)
    src_ref[...] = source[:, None]


def _fused_kernel(words_ref, tri_ref, quad_ref, bi_ref, root_ref, src_ref,
                  *, n_groups: int, match: str):
    w = words_ref[...]                             # (bb, 16) int32
    key_cols, val_cols = sdp.candidate_columns(w)  # stages 1-4, 30 columns
    n_slots = n_groups * N_CAND
    keys = jnp.stack(key_cols[:n_slots], axis=1)   # (bb, n_slots)
    valid = jnp.stack(val_cols[:n_slots], axis=1) > 0

    dicts = {"tri": tri_ref[...].reshape(-1),
             "quad": quad_ref[...].reshape(-1),
             "bi": bi_ref[...].reshape(-1)}

    # ---- stage 5a: Compare — per-group match against the resident dict ---
    hit_cols = []
    for g in range(n_groups):
        kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
        d = dicts[GROUP_DICTS[g]]
        hit_cols.append(sm.bsearch_hit(d, kg) if match == "bsearch"
                        else _bank_hit(d, kg))
    hits = jnp.concatenate(hit_cols, axis=1) & valid   # (bb, n_slots)

    # ---- stage 5b ----
    _priority_select(keys, hits.astype(jnp.int32), root_ref, src_ref,
                     n_groups=n_groups)


def _dict_slots(name: str, n_groups: int) -> list:
    """Candidate-slot columns fed by dictionary ``name`` (static)."""
    return [g * N_CAND + c for g in range(n_groups)
            if GROUP_DICTS[g] == name for c in range(N_CAND)]


def _visit_tables(keys, valid, tiles: sm.DictTileSet, *, n_groups: int,
                  block_b: int, skip_index: bool):
    """The tile-skipping pre-pass: per-batch-tile dictionary tile-visit
    index from the candidate keys and the sorted tile boundary tables.

    For every batch tile and every dictionary the live candidate keys'
    [min, max] range intersected with the tiles' sorted [mins, maxs]
    boundaries bounds which tiles can hold a hit; because the tiles
    partition a sorted dictionary, each key in fact lands in at most ONE
    tile — `searchsorted(mins, key) - 1`, kept only when the key also
    falls under that tile's max — so the mask marks exactly the landing
    tiles (a strict refinement of the range intersection). A hit requires
    key ∈ dictionary, which implies the key lands in its tile, so
    sweeping only marked tiles is bit-identical to the full sweep.

    keys int32[bp, n_slots], valid bool[bp, n_slots] (stages 1-4 output
    for the padded batch) ->

      n_visits  int32[batch_tiles]           live tiles per batch tile
      visit_idx int32[batch_tiles, n_tiles]  global tile ids, the
                n_visits live ones packed to the front in ascending
                order (pad entries are never fetched)

    skip_index=False marks every tile of every swept dictionary (bi is
    still excluded for infix=False) — the full-sweep baseline through
    the same kernel.
    """
    bt = keys.shape[0] // block_b
    tri_t, quad_t, bi_t = tiles.counts
    masks = []
    for name, base, td in (("tri", 0, tri_t), ("quad", tri_t, quad_t),
                           ("bi", tri_t + quad_t, bi_t)):
        slots = _dict_slots(name, n_groups)
        if not slots:                # bi with infix=False: never swept
            masks.append(jnp.zeros((bt, td), bool))
            continue
        if not skip_index:           # full sweep: every tile of the dict
            masks.append(jnp.ones((bt, td), bool))
            continue
        mins = tiles.mins[base:base + td]
        maxs = tiles.maxs[base:base + td]
        k = jnp.where(valid[:, slots], keys[:, slots], _KEY_NOWHERE)
        k = k.reshape(bt, -1)        # [bt, block_b * n_dict_slots]
        t = jnp.clip(jnp.searchsorted(mins, k, side="right") - 1, 0, td - 1)
        lands = (jnp.take(mins, t) <= k) & (k <= jnp.take(maxs, t))
        bi_idx = jnp.broadcast_to(jnp.arange(bt)[:, None], k.shape)
        mask = jnp.zeros((bt, td), bool)
        mask = mask.at[bi_idx.reshape(-1), t.reshape(-1)].max(lands.reshape(-1))
        masks.append(mask)
    mask = jnp.concatenate(masks, axis=1)              # [bt, n_tiles]
    n_visits = mask.sum(axis=1).astype(jnp.int32)
    # stable argsort on ~mask packs the marked tile ids to the front,
    # ascending — the visit order stays the sorted [tri | quad | bi] order
    visit_idx = jnp.argsort(~mask, axis=1, stable=True).astype(jnp.int32)
    return n_visits, visit_idx


def _fused_pipeline_kernel(nvis_ref, vis_ref, words_ref, dict_ref,
                           root_ref, src_ref, dict_bufs, hits_sc, dma_sems,
                           *, n_groups: int, match: str, num_buffers: int,
                           dict_block_r: int, tri_tiles: int,
                           quad_tiles: int):
    """Streamed Compare: grid (batch_tiles,), explicit DMA ladder inside.

    The dictionary stream stays in HBM (memory_space=ANY); the kernel
    walks this batch tile's visit list (scalar-prefetched ``vis_ref``,
    ``nvis_ref[i]`` entries) and drives a ``num_buffers``-deep rotating
    make_async_copy ladder: the copy for visit k + num_buffers - 1 is
    issued before visit k's compare runs, so tile DMA overlaps the
    bsearch/bank compute with a tunable lookahead (num_buffers=1 is the
    no-overlap baseline). Which dictionary a tile feeds is a static
    boundary compare on its *global tile id* (not the loop index — the
    visit list has holes where tiles were skipped). Each tile is
    internally sorted, so its first/last element still gives the fine
    [min, max] reject below the pre-pass' coarse one.
    """
    i = pl.program_id(0)
    n = nvis_ref[i]
    n_slots = n_groups * N_CAND
    w = words_ref[...]                             # (bb, 16) int32
    key_cols, val_cols = sdp.candidate_columns(w)  # stages 1-4
    keys = jnp.stack(key_cols[:n_slots], axis=1)
    valid = jnp.stack(val_cols[:n_slots], axis=1) > 0
    hits_sc[...] = jnp.zeros_like(hits_sc)

    def tile_dma(k, slot):
        t = vis_ref[i, k]
        return pltpu.make_async_copy(
            dict_ref.at[pl.ds(t * dict_block_r, dict_block_r), :],
            dict_bufs.at[slot], dma_sems.at[slot])

    for s in range(num_buffers - 1):               # warm the ladder
        @pl.when(s < n)
        def _start(s=s):
            tile_dma(s, s).start()

    def visit(k, carry):
        look = k + num_buffers - 1                 # ladder lookahead
        @pl.when(look < n)
        def _fetch_ahead():
            tile_dma(look, jax.lax.rem(look, num_buffers)).start()
        slot = jax.lax.rem(k, num_buffers)
        tile_dma(k, slot).wait()
        tile_id = vis_ref[i, k]
        tile = dict_bufs[slot].reshape(-1)         # (dict_block_r * LANE,)

        # which dictionary holds this tile? static boundaries on tile_id
        dict_active = {
            "tri": tile_id < tri_tiles,
            "quad": (tile_id >= tri_tiles) & (tile_id < tri_tiles + quad_tiles),
            "bi": tile_id >= tri_tiles + quad_tiles}
        slot_active = jnp.concatenate(
            [jnp.broadcast_to(dict_active[GROUP_DICTS[g]], (N_CAND,))
             for g in range(n_groups)])            # (n_slots,)

        # fine tile-range reject: tiles are internally sorted
        in_range = ((keys >= tile[0]) & (keys <= tile[-1])
                    & valid & slot_active[None, :])

        @pl.when(in_range.any())
        def _compare():                            # stage 5a on this tile
            hit_cols = []
            for g in range(n_groups):
                kg = keys[:, g * N_CAND : (g + 1) * N_CAND]
                hit = (sm.bsearch_hit(tile, kg) if match == "bsearch"
                       else _bank_hit(tile, kg))
                hit_cols.append(hit & dict_active[GROUP_DICTS[g]])
            hits = jnp.concatenate(hit_cols, axis=1) & valid
            hits_sc[...] |= hits.astype(jnp.int32)
        return carry

    jax.lax.fori_loop(0, n, visit, 0)
    _priority_select(keys, hits_sc[...], root_ref, src_ref,
                     n_groups=n_groups)            # stage 5b


@functools.partial(
    jax.jit, static_argnames=("infix", "match", "block_b", "residency",
                              "dict_block_r", "num_buffers", "skip_index",
                              "interpret"))
def stem_fused_pallas(
    words: jnp.ndarray,
    roots,
    *,
    infix: bool = True,
    match: str = "bsearch",
    block_b: int = 256,
    residency: str = "auto",
    dict_block_r: int = 8,
    num_buffers: int = 2,
    skip_index: bool = True,
    interpret: bool = False,
):
    """words int32[B,16] + RootDictArrays -> (root int32[B,4], source int32[B]).

    Single ``pallas_call`` either way; ``residency`` picks the dictionary
    layout (DESIGN.md §5.3):

      "resident"  grid = batch tiles only; the packed dictionaries ride
                  along as constant-index-map VMEM blocks. Raises past
                  MAX_RESIDENT_KEYS (it would thrash VMEM).
      "streamed"  grid = batch tiles; per batch tile the kernel sweeps a
                  scalar-prefetched visit list of (dict_block_r x 128)
                  dictionary tiles, DMA'd from HBM through a
                  ``num_buffers``-deep explicit ladder; with
                  ``skip_index`` only the tiles a candidate key can land
                  in are visited at all. The visit table itself costs
                  ``batch_tiles x n_tiles`` int32 of scalar-prefetch
                  (SMEM) space — 256K keys at dict_block_r=8 with 32
                  batch tiles is ~33 KB; very large batch x dictionary
                  products should raise dict_block_r (or chunk the
                  batch, as serving's fixed super-tiles already do) to
                  stay inside scalar memory on real hardware.
      "auto"      resident while the dictionaries fit, streamed beyond.

    ``num_buffers`` (1..4; streamed only) sets the DMA lookahead depth —
    2 double-buffers, 1 is the no-overlap baseline. ``skip_index=False``
    (streamed only) disables tile skipping and sweeps every tile of the
    loaded dictionaries through the same ladder.

    Bit-identical to ``core.stemmer.extract_roots`` (and pyref) in every
    (residency, match, num_buffers, skip_index) combination.

    ``roots`` also accepts a ``core.stemmer.ResolvedRootDict`` handle:
    its pinned residency replaces the residency argument, and a handle
    carrying a prebuilt ``stem_match.DictTileSet`` of matching
    dict_block_r skips the per-call pad/concat of the tile stream
    (serving resolves both once at dictionary-publish time, so a hot
    swap whose arrays keep their shapes replays the cached trace).
    """
    if match not in ("bank", "bsearch"):
        raise ValueError(f"unknown in-kernel match strategy: {match}")
    if not 1 <= num_buffers <= MAX_NUM_BUFFERS:
        raise ValueError(f"num_buffers must be in 1..{MAX_NUM_BUFFERS},"
                         f" got {num_buffers}")
    n_groups = 5 if infix else 2
    roots, residency, tiles = core_stemmer.unwrap_dict(roots, residency)
    residency = choose_residency(roots, residency, infix=infix)

    loaded = _loaded_keys(roots, infix)
    if residency == "resident" and loaded > MAX_RESIDENT_KEYS:
        raise ValueError(
            f"dictionaries too large for VMEM residency ({loaded} keys >"
            f" {MAX_RESIDENT_KEYS}); use residency='streamed' or 'auto'"
            " (DESIGN.md §5.3)")

    b = words.shape[0]
    if b == 0:  # degenerate batch: nothing to launch
        return (jnp.zeros((0, 4), jnp.int32), jnp.zeros((0,), jnp.int32))
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    bp = wp.shape[0]

    word_spec = pl.BlockSpec((block_b, ab.MAXLEN), lambda i, *a: (i, 0))
    out_specs = [pl.BlockSpec((block_b, 4), lambda i, *a: (i, 0)),
                 pl.BlockSpec((block_b, 1), lambda i, *a: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((bp, 4), jnp.int32),
                 jax.ShapeDtypeStruct((bp, 1), jnp.int32)]

    if residency == "resident":
        prep = sm.pad_dict_sorted if match == "bsearch" else sm.pad_dict_lanes
        # infix=False never reads the bi dict: ship a one-lane placeholder
        # so the unused table doesn't occupy VMEM (see choose_residency)
        bi = roots.bi if infix else jnp.full((1,), sm.DICT_PAD, jnp.int32)
        tri2, quad2, bi2 = prep(roots.tri), prep(roots.quad), prep(bi)
        dict_spec = lambda d: pl.BlockSpec(d.shape, lambda i: (0, 0))
        root, source = pl.pallas_call(
            functools.partial(_fused_kernel, n_groups=n_groups, match=match),
            grid=(bp // block_b,),
            in_specs=[word_spec,
                      dict_spec(tri2), dict_spec(quad2), dict_spec(bi2)],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(wp, tri2, quad2, bi2)
        return root[:b], source[:b, 0]

    # ---- streamed: scalar-prefetched visit index + explicit DMA ladder ---
    if tiles is None or tiles.dict_block_r != dict_block_r:
        tiles = sm.build_dict_tiles(roots.tri, roots.quad, roots.bi,
                                    dict_block_r)
    tri_tiles, quad_tiles, _ = tiles.counts
    n_slots = n_groups * N_CAND

    # pre-pass (stages 1-4 in jnp, the same candidate_columns body the
    # kernel runs): which dictionary tiles can this batch tile hit?
    kc, vc = sdp.candidate_columns(wp)
    n_visits, visit_idx = _visit_tables(
        jnp.stack(kc[:n_slots], axis=1), jnp.stack(vc[:n_slots], axis=1) > 0,
        tiles, n_groups=n_groups, block_b=block_b, skip_index=skip_index)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # (n_visits, visit_idx) -> SMEM
        grid=(bp // block_b,),
        in_specs=[word_spec,
                  pl.BlockSpec(memory_space=pltpu.ANY)],  # dict stays in HBM
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((num_buffers, dict_block_r, sm.LANE), jnp.int32),
            pltpu.VMEM((block_b, n_slots), jnp.int32),
            pltpu.SemaphoreType.DMA((num_buffers,)),
        ],
    )
    root, source = pl.pallas_call(
        functools.partial(_fused_pipeline_kernel, n_groups=n_groups,
                          match=match, num_buffers=num_buffers,
                          dict_block_r=dict_block_r, tri_tiles=tri_tiles,
                          quad_tiles=quad_tiles),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(n_visits, visit_idx, wp, tiles.stream)
    return root[:b], source[:b, 0]


def tile_visit_stats(words, roots, *, infix: bool = True, block_b: int = 256,
                     dict_block_r: int = 8, skip_index: bool = True) -> dict:
    """Run only the tile-skipping pre-pass and report visit counts.

    Returns ``{"visited": total tile visits across batch tiles,
    "full_sweep": batch_tiles * live dictionary tiles (what
    skip_index=False visits), "batch_tiles", "dict_tiles"}`` — the
    numbers the ``dict_stream_pipeline`` benchmark rows record so the
    skip index's coverage is tracked next to its timings.
    """
    roots, _, tiles = core_stemmer.unwrap_dict(roots, "auto")
    if tiles is None or tiles.dict_block_r != dict_block_r:
        tiles = sm.build_dict_tiles(roots.tri, roots.quad, roots.bi,
                                    dict_block_r)
    n_groups = 5 if infix else 2
    b = words.shape[0]
    pad = (-b) % block_b
    wp = jnp.pad(words, ((0, pad), (0, 0)))
    n_slots = n_groups * N_CAND
    kc, vc = sdp.candidate_columns(wp)
    n_visits, _ = _visit_tables(
        jnp.stack(kc[:n_slots], axis=1), jnp.stack(vc[:n_slots], axis=1) > 0,
        tiles, n_groups=n_groups, block_b=block_b, skip_index=skip_index)
    bt = wp.shape[0] // block_b
    tri_t, quad_t, bi_t = tiles.counts
    live = tri_t + quad_t + (bi_t if infix else 0)
    return {"visited": int(jnp.sum(n_visits)), "full_sweep": bt * live,
            "batch_tiles": bt, "dict_tiles": live}
