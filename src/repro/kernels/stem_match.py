"""Pallas TPU kernel: tiled dictionary match (the paper's Compare stage).

The FPGA datapath instantiates banks of ``stem3/4_Comparator`` units that
compare candidate stems against stored roots in parallel. On TPU the root
dictionary lives in HBM and is streamed tile-by-tile through VMEM while a
tile of packed 24-bit candidate keys stays resident; each grid step performs
an all-pairs equality compare on the VPU and ORs the row-reduction into the
output tile.

Layout: both keys and dictionary are reshaped to (rows, 128) so the minor
dimension matches the VPU lane width; a (block_n x 128) key tile against a
(block_r x 128) dictionary tile compares (block_n*128) x (block_r*128)
pairs per step — the TPU analogue of the comparator bank, with the bank
"size" set by BlockSpec rather than LUT count.

Padding: keys are padded with -1 and the dictionary with -2, so padding
never produces a match.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
KEY_PAD = -1
DICT_PAD = -2
# bsearch padding sentinel: larger than any packed 24-bit key, so padding a
# sorted dictionary on the right keeps it sorted and never matches.
DICT_SENTINEL = 1 << 28


def _ceil_log2(n: int) -> int:
    k = 0
    while (1 << k) < n:
        k += 1
    return k


def pad_dict_lanes(dict_keys: jnp.ndarray) -> jnp.ndarray:
    """Pad to a LANE multiple with DICT_PAD and reshape (rows, LANE)."""
    r = dict_keys.shape[0]
    r_pad = (-r) % LANE
    return jnp.pad(dict_keys, (0, r_pad), constant_values=DICT_PAD).reshape(-1, LANE)


def pad_dict_sorted(dict_keys: jnp.ndarray) -> jnp.ndarray:
    """Pad a *sorted* dictionary to the next pow2 >= LANE with DICT_SENTINEL,
    reshaped (rows, LANE) so it ships to VMEM as a lane-aligned 2D tile."""
    r = dict_keys.shape[0]
    rp = max(LANE, 1 << _ceil_log2(r))
    return jnp.pad(dict_keys, (0, rp - r),
                   constant_values=DICT_SENTINEL).reshape(-1, LANE)


def pad_dict_tiles(dict_keys: jnp.ndarray, tile_rows: int) -> jnp.ndarray:
    """Pad a *sorted* dictionary to a whole number of (tile_rows, LANE) tiles
    with DICT_SENTINEL and reshape (n_tiles * tile_rows, LANE).

    Sentinel padding on the right keeps every tile internally sorted, so a
    consumer can binary-search each tile independently and use the tile's
    first/last element as a [min, max] range reject (the streamed megakernel
    Compare path, stem_fused._fused_pipeline_kernel). Empty / placeholder
    dictionaries still produce one full sentinel tile.
    """
    r = dict_keys.shape[0]
    per_tile = tile_rows * LANE
    rp = max(per_tile, ((r + per_tile - 1) // per_tile) * per_tile)
    return jnp.pad(dict_keys, (0, rp - r),
                   constant_values=DICT_SENTINEL).reshape(-1, LANE)


@jax.tree_util.register_pytree_node_class
@dataclass
class DictTileSet:
    """The streamed megakernel's dictionary layout, prebuilt.

    ``stream`` is the concatenated `[tri | quad | bi]` tile stream from
    :func:`pad_dict_tiles` (each `(dict_block_r x LANE)` tile internally
    sorted, sentinel-padded); ``mins`` / ``maxs`` are the per-tile sorted
    boundary tables (first/last element of every tile) that the tile-visit
    pre-pass intersects candidate keys against (stem_fused._visit_tables).
    Tile counts and the tile height ride as pytree aux data, so a jit
    trace is keyed on them: serving precomputes a DictTileSet once at
    dictionary-publish time (serve.DictStore -> core.stemmer.resolve_dict)
    and every launch — including hot swaps whose shapes match — replays
    the cached trace without re-padding or re-concatenating the tables.
    """

    stream: jnp.ndarray            # int32 [n_tiles * dict_block_r, LANE]
    mins: jnp.ndarray              # int32 [n_tiles] first element per tile
    maxs: jnp.ndarray              # int32 [n_tiles] last element per tile
    dict_block_r: int              # tile height in LANE rows (static)
    counts: tuple                  # (tri_tiles, quad_tiles, bi_tiles) (static)

    def tree_flatten(self):
        return ((self.stream, self.mins, self.maxs),
                (self.dict_block_r, self.counts))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_tiles(self) -> int:
        return sum(self.counts)


def build_dict_tiles(tri: jnp.ndarray, quad: jnp.ndarray, bi: jnp.ndarray,
                     dict_block_r: int) -> DictTileSet:
    """Pad + concatenate the three sorted dictionaries into the streamed
    tile stream and extract the per-tile [min, max] boundary tables.

    All three dictionaries are always present in the stream (the bi table
    too, even for infix=False sweeps): with the tile-visit index an unused
    table's tiles are simply never visited, and a single layout keeps one
    jit trace per shape regardless of the infix flag.
    """
    tiles = [pad_dict_tiles(d, dict_block_r) for d in (tri, quad, bi)]
    counts = tuple(t.shape[0] // dict_block_r for t in tiles)
    stream = jnp.concatenate(tiles, axis=0)
    flat = stream.reshape(-1, dict_block_r * LANE)   # one row per tile
    return DictTileSet(stream=stream, mins=flat[:, 0], maxs=flat[:, -1],
                       dict_block_r=dict_block_r, counts=counts)


def bsearch_hit(flat_dict: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """Membership via an unrolled branchless binary search.

    flat_dict int32[Rp] sorted ascending, Rp a power of two (sentinel
    padded); keys int32[...] -> bool[...]. Exactly ceil(log2 Rp) static
    bisection steps — the paper's §7 'tree search' Compare upgrade: each
    step halves the [lo, hi] window with a predicated select instead of a
    branch, so the whole search is a fixed-depth dataflow graph (the TPU
    analogue of a pipelined hardware tree walker).
    """
    rp = flat_dict.shape[0]
    lo = jnp.zeros(keys.shape, jnp.int32)
    hi = jnp.full(keys.shape, rp - 1, jnp.int32)
    for _ in range(_ceil_log2(rp)):
        mid = (lo + hi) // 2
        v = jnp.take(flat_dict, mid, mode="clip")
        ge = v >= keys
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, mid + 1)
    return jnp.take(flat_dict, lo, mode="clip") == keys


def _match_kernel(keys_ref, dict_ref, out_ref):
    """Grid (n_tiles, r_tiles); r (minor) accumulates OR into out_ref."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]          # (bn, LANE) int32
    dic = dict_ref[...]           # (br, LANE) int32
    bn, _ = keys.shape
    # all-pairs compare: (bn*LANE, 1) vs (1, br*LANE)
    k_flat = keys.reshape(bn * LANE, 1)
    d_flat = dic.reshape(1, -1)
    hit = (k_flat == d_flat).any(axis=1).reshape(bn, LANE)
    out_ref[...] |= hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_r", "interpret"))
def dict_match_pallas(
    keys: jnp.ndarray,
    dict_keys: jnp.ndarray,
    *,
    block_n: int = 2,
    block_r: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """keys int32[N], dict_keys int32[R] -> bool[N] membership flags."""
    n = keys.shape[0]
    r = dict_keys.shape[0]

    n_pad = (-n) % (block_n * LANE)
    r_pad = (-r) % (block_r * LANE)
    keys_p = jnp.pad(keys, (0, n_pad), constant_values=KEY_PAD).reshape(-1, LANE)
    dict_p = jnp.pad(dict_keys, (0, r_pad), constant_values=DICT_PAD).reshape(-1, LANE)

    n_tiles = keys_p.shape[0] // block_n
    r_tiles = dict_p.shape[0] // block_r

    out = pl.pallas_call(
        _match_kernel,
        grid=(n_tiles, r_tiles),
        in_specs=[
            pl.BlockSpec((block_n, LANE), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, LANE), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, LANE), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(keys_p.shape, jnp.int32),
        interpret=interpret,
    )(keys_p, dict_p)
    return out.reshape(-1)[:n].astype(bool)


# ---------------------------------------------------------------------------
# O(log R) variant: in-kernel sorted search, dictionary resident in VMEM
# ---------------------------------------------------------------------------
def _bsearch_kernel(keys_ref, dict_ref, out_ref):
    """Grid (n_tiles,); the whole (sentinel-padded) dictionary rides along
    as a VMEM-resident block (constant index map), so one launch covers all
    key tiles with no HBM round-trips between bisection steps."""
    keys = keys_ref[...]                      # (bn, LANE) int32
    flat = dict_ref[...].reshape(-1)          # (Rp,) sorted + sentinel
    out_ref[...] = bsearch_hit(flat, keys).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dict_match_bsearch_pallas(
    keys: jnp.ndarray,
    dict_keys: jnp.ndarray,
    *,
    block_n: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """keys int32[N], dict_keys int32[R] *sorted* -> bool[N].

    O(N log R) compare — the paper's proposed tree-search upgrade run
    inside the kernel: ceil(log2 R) predicated bisection steps per key
    against the VMEM-resident sorted dictionary.
    """
    n = keys.shape[0]
    n_pad = (-n) % (block_n * LANE)
    keys_p = jnp.pad(keys, (0, n_pad), constant_values=KEY_PAD).reshape(-1, LANE)
    dict_p = pad_dict_sorted(dict_keys)

    n_tiles = keys_p.shape[0] // block_n
    out = pl.pallas_call(
        _bsearch_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((block_n, LANE), lambda i: (i, 0)),
            pl.BlockSpec(dict_p.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(keys_p.shape, jnp.int32),
        interpret=interpret,
    )(keys_p, dict_p)
    return out.reshape(-1)[:n].astype(bool)
