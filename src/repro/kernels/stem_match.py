"""Pallas TPU kernel: tiled dictionary match (the paper's Compare stage).

The FPGA datapath instantiates banks of ``stem3/4_Comparator`` units that
compare candidate stems against stored roots in parallel. On TPU the root
dictionary lives in HBM and is streamed tile-by-tile through VMEM while a
tile of packed 24-bit candidate keys stays resident; each grid step performs
an all-pairs equality compare on the VPU and ORs the row-reduction into the
output tile.

Layout: both keys and dictionary are reshaped to (rows, 128) so the minor
dimension matches the VPU lane width; a (block_n x 128) key tile against a
(block_r x 128) dictionary tile compares (block_n*128) x (block_r*128)
pairs per step — the TPU analogue of the comparator bank, with the bank
"size" set by BlockSpec rather than LUT count.

Padding: keys are padded with -1 and the dictionary with -2, so padding
never produces a match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
KEY_PAD = -1
DICT_PAD = -2


def _match_kernel(keys_ref, dict_ref, out_ref):
    """Grid (n_tiles, r_tiles); r (minor) accumulates OR into out_ref."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]          # (bn, LANE) int32
    dic = dict_ref[...]           # (br, LANE) int32
    bn, _ = keys.shape
    # all-pairs compare: (bn*LANE, 1) vs (1, br*LANE)
    k_flat = keys.reshape(bn * LANE, 1)
    d_flat = dic.reshape(1, -1)
    hit = (k_flat == d_flat).any(axis=1).reshape(bn, LANE)
    out_ref[...] |= hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_r", "interpret"))
def dict_match_pallas(
    keys: jnp.ndarray,
    dict_keys: jnp.ndarray,
    *,
    block_n: int = 2,
    block_r: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """keys int32[N], dict_keys int32[R] -> bool[N] membership flags."""
    n = keys.shape[0]
    r = dict_keys.shape[0]

    n_pad = (-n) % (block_n * LANE)
    r_pad = (-r) % (block_r * LANE)
    keys_p = jnp.pad(keys, (0, n_pad), constant_values=KEY_PAD).reshape(-1, LANE)
    dict_p = jnp.pad(dict_keys, (0, r_pad), constant_values=DICT_PAD).reshape(-1, LANE)

    n_tiles = keys_p.shape[0] // block_n
    r_tiles = dict_p.shape[0] // block_r

    out = pl.pallas_call(
        _match_kernel,
        grid=(n_tiles, r_tiles),
        in_specs=[
            pl.BlockSpec((block_n, LANE), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, LANE), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, LANE), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(keys_p.shape, jnp.int32),
        interpret=interpret,
    )(keys_p, dict_p)
    return out.reshape(-1)[:n].astype(bool)
