"""Pallas TPU kernel: the raw-text ingestion front-end (DESIGN.md §7).

One launch turns a padded codepoint tile into normalised, clitic-stripped
`[block_w, 16]` word-tile rows — the exact input `stem_fused_pallas`
consumes — so text feeds the stemmer megakernel with no host round-trip:

  grid = (Wp / block_w,)   one step per word tile
  chars     VMEM-resident (constant index map), gathered per word
  starts    int32[Wp, 1]   word start char indices (geometry pre-pass)
  lens      int32[Wp, 1]   raw codepoint counts
  lut       (2, 128)       textnorm.CLASS_LUT as a lane-aligned tile
  fw        (Fp/128, 128)  textnorm.FW_FLAT function-word keys (sorted,
                           sentinel-padded pow2 — pad_dict_sorted layout)

The kernel body is gather-based where the jnp reference
(``textnorm.frontend_reference``) is scatter-based: each word reads its
MAX_RAW-codepoint raw window with one ``jnp.take``, classifies through
the LUT, compacts letters with the unrolled cumsum==k one-hot pattern
(no in-kernel argsort/gather along traced offsets), then hands the
letter rows to the *shared* ``textnorm.strip_and_pack`` body — the same
traced code both paths run, so clitic stripping cannot drift between
reference and kernel. Word geometry (starts/lens/byte spans) comes from
``textnorm.segment_geometry``, a jnp pre-pass in the same jit scope —
the PR 5 visit-index precedent: cheap irregular indexing work stays in
XLA, the dense per-word normalisation runs in the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import alphabet as ab
from repro.core import textnorm as tn

LANE = 128


def _frontend_kernel(starts_ref, lens_ref, chars_ref, lut_ref, fw_ref,
                     words_ref):
    starts = starts_ref[...][:, 0]                 # [bw]
    lens = lens_ref[...][:, 0]                     # [bw]
    flat = chars_ref[...].reshape(-1)              # [Tp]
    lut = lut_ref[...].reshape(-1)                 # [256]
    fw = fw_ref[...].reshape(-1)                   # [Fp]
    bw = starts.shape[0]

    j = jax.lax.broadcasted_iota(jnp.int32, (bw, tn.MAX_RAW), 1)
    idx = starts[:, None] + j
    raw = jnp.take(flat, jnp.clip(idx, 0, flat.shape[0] - 1), mode="clip")
    live = j < jnp.minimum(lens, tn.MAX_RAW)[:, None]
    cls = jnp.where(live, tn.classify_codes(raw, lut), tn.CLS_SEP)

    # compact letters left: position k letter = the column whose running
    # letter count hits k+1 (cumsum one-hot; same trick as the fused
    # kernel's _priority_select — no gather along traced offsets)
    is_letter = cls > 0
    csum = jnp.cumsum(is_letter.astype(jnp.int32), axis=1)
    nlet = jnp.minimum(csum[:, -1], tn.CMAX)
    cols = [jnp.sum(jnp.where(is_letter & (csum == k + 1), cls, 0), axis=1)
            for k in range(tn.CMAX)]
    codes = jnp.stack(cols, axis=1)                # [bw, CMAX]

    words_ref[...] = tn.strip_and_pack(codes, nlet, fw)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def text_frontend_pallas(chars, starts, lens, *, block_w: int = 128,
                         interpret: bool = False):
    """chars int32[T] codepoints (0-padded), starts/lens int32[Wp] from
    ``textnorm.segment_geometry`` (Wp a block_w multiple) -> words
    int32[Wp, 16], bit-identical to ``textnorm.frontend_reference`` and
    to the host ``analyze_text_py`` rows.
    """
    chars = jnp.asarray(chars, jnp.int32)
    starts = jnp.asarray(starts, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    wp = starts.shape[0]
    if wp % block_w:
        raise ValueError(f"word capacity {wp} not a multiple of"
                         f" block_w={block_w}")
    t_pad = (-chars.shape[0]) % LANE
    chars2 = jnp.pad(chars, (0, t_pad)).reshape(-1, LANE)
    lut2 = jnp.asarray(tn.CLASS_LUT).reshape(2, LANE)
    fw2 = jnp.asarray(tn.FW_FLAT).reshape(-1, LANE)

    return pl.pallas_call(
        _frontend_kernel,
        grid=(wp // block_w,),
        in_specs=[
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_w, 1), lambda i: (i, 0)),
            pl.BlockSpec(chars2.shape, lambda i: (0, 0)),
            pl.BlockSpec(lut2.shape, lambda i: (0, 0)),
            pl.BlockSpec(fw2.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, ab.MAXLEN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, ab.MAXLEN), jnp.int32),
        interpret=interpret,
    )(starts[:, None], lens[:, None], chars2, lut2, fw2)
