"""Model / run configuration dataclasses + the assigned input-shape suite."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    family: str = "dense"       # dense | moe | ssm | hybrid | vlm | audio
    block: str = "attn"         # attn | mamba | hymba
    ffn: str = "swiglu"         # swiglu | geglu
    attn_impl: str = "gqa"      # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 5e5
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False   # gemma multiplies embeddings by sqrt(d)
    sliding_window: int = 0     # 0 = full attention
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0        # leading dense layers (DeepSeek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # MLA (DeepSeek)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (Mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0
    ssm_chunk: int = 256
    # cross-attention (VLM): groups of (1 cross + group_self self) layers
    n_cross_layers: int = 0
    group_self: int = 0
    vision_seq: int = 0
    # audio
    n_codebooks: int = 0
    # analysis (see models/scan_utils.py)
    unroll_scans: bool = False
    loss_chunk: int = 512   # fused-CE block; bigger = fewer head re-gathers
    # serving
    kv_quant: bool = False  # int8 KV cache (decode memory floor /2)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


# The assigned input-shape suite (identical for all 10 archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic global context: only SSM/hybrid run it
# (the 8 pure-full-attention skips are recorded in DESIGN.md §4).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in LONG_CONTEXT_FAMILIES:
        names.append("long_500k")
    return names


@dataclass(frozen=True)
class RunConfig:
    """Training-run / serving-run level knobs."""
    model: ModelConfig
    shape: ShapeConfig
    learning_rate: float = 3e-4
    lr_warmup: int = 100
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: str = "full"         # none | dots | full
    microbatches: int = 1       # gradient accumulation
    zero1: bool = True          # shard optimizer state over the data axis
    grad_compression: str = "none"  # none | int8ef
    profile: str = "default"        # sharding profile (dist/sharding.py)
    context_parallel: bool = False


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab=512,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
    )
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=2, d_ff_expert=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense=min(cfg.first_dense, 1))
    if cfg.attn_impl == "mla":
        kw.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.block in ("mamba", "hymba"):
        kw.update(ssm_state=8, dt_rank=8, ssm_chunk=16)
    if cfg.n_cross_layers:
        kw.update(n_cross_layers=2, group_self=1, n_layers=2, vision_seq=16)
    if cfg.n_codebooks:
        kw.update(n_codebooks=cfg.n_codebooks)
    return replace(cfg, **kw)
