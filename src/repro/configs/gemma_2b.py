"""gemma-2b [dense] — GeGLU, head_dim=256, MQA, 256k vocab [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=256000,
        family="dense",
        ffn="geglu",
        tie_embeddings=True,
        embed_scale=True,
        rope_theta=10000.0,
    )
