"""musicgen-medium [audio] — decoder-only over EnCodec tokens, 4 codebooks
[arXiv:2306.05284].

The EnCodec frontend is a STUB per the assignment: tokens are 4 parallel
codebook streams [B, T, 4]; embeddings are summed, 4 output heads. The
delay-pattern interleaving is a serving-side detail outside the backbone.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,  # MHA
        head_dim=64,
        d_ff=6144,
        vocab=2048,
        family="audio",
        ffn="mlp",
        n_codebooks=4,
        rope_theta=10000.0,
    )
