"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block
[arXiv:2411.13676].

Hymba pairs sliding-window attention with global-context SSM heads; we
model that as SWA(1024) attention + full Mamba in every block, which is
what makes long_500k decoding O(window + state) per step.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        family="hybrid",
        block="hymba",
        ssm_state=16,
        d_conv=4,
        expand=2,
        sliding_window=1024,
        rope_theta=10000.0,
        ssm_chunk=256,
    )
