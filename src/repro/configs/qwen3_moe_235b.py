"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, GQA kv=4
[hf:Qwen/Qwen3 family]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=0,  # every layer is MoE
        vocab=151936,
        family="moe",
        n_experts=128,
        top_k=8,
        d_ff_expert=1536,
        rope_theta=1000000.0,
    )
