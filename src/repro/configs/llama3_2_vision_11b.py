"""llama-3.2-vision-11b [vlm] — 32 self + 8 interleaved cross-attention
layers (40 total), GQA kv=8 [hf:meta-llama/Llama-3.2-11B-Vision].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, 1601, d_model]; only the transformer
backbone is modelled.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        n_layers=32,          # self-attention layers
        n_cross_layers=8,     # +8 cross layers -> 40 total
        group_self=4,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=128256,
        family="vlm",
        vision_seq=1601,
        rope_theta=500000.0,
    )
