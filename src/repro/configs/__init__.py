"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    shapes_for,
    smoke_config,
)

ARCHS = {
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "qwen2.5-14b": "qwen2_5_14b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma-2b": "gemma_2b",
    "llama3-8b": "llama3_8b",
    "hymba-1.5b": "hymba_1_5b",
    "musicgen-medium": "musicgen_medium",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.config()


def all_configs() -> dict[str, ModelConfig]:
    return {name: get_config(name) for name in ARCHS}
