"""deepseek-coder-33b [dense] — llama-arch, GQA kv=8 [arXiv:2401.14196]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab=32256,
        family="dense",
        rope_theta=100000.0,
    )
