"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 64 routed experts top-6 +
2 shared, first layer dense [arXiv:2405.04434].

The assignment line also quotes the full-V2 expert count (160); we build
the Lite config it names: 27L, d_model 2048, 64 routed experts.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=192,  # qk_nope + qk_rope
        d_ff=10944,    # the leading dense layer
        vocab=102400,
        family="moe",
        attn_impl="mla",
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64,
        top_k=6,
        n_shared_experts=2,
        d_ff_expert=1408,
        first_dense=1,
        rope_theta=10000.0,
    )
