"""Configurations for the paper's own system (the stemmer pipeline)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class StemmerConfig:
    """Mirrors the paper's processor parameters + our TPU batch knobs."""

    dict_tri: int = 2000          # trilateral dictionary size
    dict_quad: int = 200
    infix: bool = True            # §6.3 infix processing on/off
    backend: str = "sorted"       # dense | sorted | pallas
    batch: int = 65536            # words per step ("register file" width)
    microbatch: int = 4096        # pipelined-processor microbatch
    n_stages: int = 5             # paper's five pipeline stages


PRESETS = {
    "software": StemmerConfig(backend="dense", batch=1),
    "non_pipelined": StemmerConfig(backend="dense"),
    "pipelined": StemmerConfig(backend="pallas"),
    "pipelined_sorted": StemmerConfig(backend="sorted"),
}
