"""falcon-mamba-7b [ssm] — 64L attention-free Mamba-1, ssm_state=16
[arXiv:2410.05355]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        n_layers=64,
        d_model=4096,
        n_heads=1,       # unused (attention-free)
        n_kv_heads=1,
        head_dim=1,
        d_ff=0,          # mamba blocks have no separate FFN
        vocab=65024,
        family="ssm",
        block="mamba",
        ssm_state=16,
        d_conv=4,
        expand=2,
        tie_embeddings=True,
        ssm_chunk=256,
    )
