"""repro.launch subpackage."""
