"""Post-compile HLO analysis: collective-byte accounting + roofline terms.

cost_analysis() gives FLOPs and bytes; collective traffic is not included,
so we parse the optimized (post-SPMD, per-device) HLO text. Operands are
printed without inline types in this mode, so per-op bytes are derived
from the RESULT shape + replica-group size:

    all-reduce          operand = result
    all-gather          operand = result / group
    reduce-scatter      operand = result * group
    all-to-all          operand = result
    collective-permute  operand = result

Two aggregates are reported per device:
  * operand_bytes  — the assignment's "sum of operand sizes",
  * wire_bytes     — ring-algorithm bytes actually crossing ICI links
                     (2(g-1)/g·x for all-reduce, (g-1)/g·x for ag/rs/a2a,
                     x for permute); the roofline collective term uses
                     wire_bytes / LINK_BW.

Shapes in the optimized module are PER-DEVICE, so dividing by LINK_BW
directly gives the per-chip link-time — equivalent to the assignment's
collective_bytes/(chips·link_bw) with global byte sums.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_RESULT_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [n_groups, group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit {{0,1,...},{...}} form; size of the first group
        return len(m.group(1).split(","))
    return 1


@dataclass
class CollectiveStats:
    operand_by_op: dict = field(default_factory=dict)
    wire_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def operand_bytes(self) -> int:
        return sum(self.operand_by_op.values())

    @property
    def wire_bytes(self) -> int:
        return sum(self.wire_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _RESULT_RE.search(line)
        if not m:
            continue
        result_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        g = max(1, _group_size(line))
        if op == "all-gather":
            operand = result_bytes // g
            wire = result_bytes * (g - 1) // g
        elif op == "reduce-scatter":
            operand = result_bytes * g
            wire = result_bytes * (g - 1)
        elif op == "all-reduce":
            operand = result_bytes
            wire = 2 * result_bytes * (g - 1) // g
        elif op == "all-to-all":
            operand = result_bytes
            wire = result_bytes * (g - 1) // g
        else:  # collective-permute: point-to-point
            operand = result_bytes
            wire = result_bytes
        stats.operand_by_op[op] = stats.operand_by_op.get(op, 0) + operand
        stats.wire_by_op[op] = stats.wire_by_op.get(op, 0) + wire
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


# ---------------------------------------------------------------------------
# Fused HBM-traffic model
# ---------------------------------------------------------------------------
# XLA:CPU's "bytes accessed" counts every op unfused (each elementwise op
# re-reads/re-writes full tensors), wildly over-stating HBM traffic vs a
# TPU where elementwise chains fuse into their producers. The fused model
# counts IO only for ops that genuinely stream HBM on TPU: dots/convs,
# gathers/scatters, reduces, dynamic-update-slices — operands + result —
# plus entry parameters (read once) and outputs (written once).
_DEF_RE = re.compile(r"%([\w.\-]+) = ([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?|\([^=]*?\))")
_TRAFFIC_OPS = ("dot(", "convolution(", "gather(", "scatter(",
                "dynamic-update-slice(", "reduce(", "reduce-window(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def hbm_traffic_model(hlo_text: str, arg_bytes: int = 0, out_bytes: int = 0,
                      dus_aliased: bool = False) -> int:
    """dus_aliased=True models donated in-place cache updates: a
    dynamic-update-slice costs only its update slice (read+write), not the
    whole buffer — the honest TPU number for decode steps. The default
    (False) is the conservative upper bound used in the §Roofline table."""
    name_bytes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.search(line)
        if m:
            name_bytes[m.group(1)] = _shape_bytes(m.group(2))
    total = arg_bytes + out_bytes
    for line in lines:
        s = line.strip()
        m = _DEF_RE.search(s)
        if not m:
            continue
        rest = s[m.end():]
        op_hit = next((op for op in _TRAFFIC_OPS if rest.lstrip().startswith(op.rstrip("(")  + "(")), None)
        if op_hit is None:
            continue
        result = _shape_bytes(m.group(2))
        call = rest.split("(", 1)[1]
        call = call.split("), ", 1)[0]
        names = _OPERAND_RE.findall(call)
        operands = sum(name_bytes.get(n, 0) for n in names)
        if dus_aliased and op_hit.startswith("dynamic-update-slice"):
            upd = name_bytes.get(names[1], 0) if len(names) > 1 else 0
            total += 2 * upd
            continue
        total += result + operands
    return total


# hardware constants: TPU v5e (assignment-provided)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes_per_dev: float,
                   chips: int) -> dict:
    """Three roofline terms in seconds (global FLOPs/bytes; per-dev wire)."""
    terms = {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": wire_bytes_per_dev / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
