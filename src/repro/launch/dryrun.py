import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: AOT-lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the production mesh (16×16 single-pod, 2×16×16 multi-pod),
  2. constructs abstract, sharded inputs (ShapeDtypeStructs — no alloc),
  3. lowers + compiles the step (train_step / prefill / decode),
  4. records memory_analysis, cost_analysis, and collective-byte stats
     parsed from the optimized HLO into benchmarks/results/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs import SHAPES, RunConfig, shapes_for  # noqa: E402
from repro.dist import sharding  # noqa: E402
from repro.launch import hlo_analysis, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as model_mod  # noqa: E402
from repro.models import params as pm  # noqa: E402
from repro.train import optimizer, train_step as ts  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def _moments_dtype(cfg):
    # bf16 moments keep the 235B MoE optimizer inside v5e HBM (DESIGN.md)
    return jnp.bfloat16 if pm.count_params(model_mod.model_spec(cfg)) > 1e11 \
        else jnp.float32


def active_params(cfg) -> int:
    """Parameters touched per token (MoE counts top_k+shared experts)."""
    spec = model_mod.model_spec(cfg)
    total = pm.count_params(spec)
    if not cfg.is_moe:
        return total
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = cfg.n_layers - cfg.first_dense
    routed_total = cfg.n_experts * per_expert * n_moe_layers
    routed_active = cfg.top_k * per_expert * n_moe_layers
    return total - routed_total + routed_active


def _param_dtype(cfg):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               profile: str = "default"):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    run = RunConfig(model=cfg, shape=shape, profile=profile)
    spec = model_mod.model_spec(cfg)
    aparams = sharding.shard_abstract(spec, mesh, _param_dtype(cfg), profile)

    if shape.kind == "train":
        step = ts.make_train_step(cfg, run, mesh)
        aopt = optimizer.abstract_state(aparams, _moments_dtype(cfg))
        abatch = input_specs.batch_specs(cfg, shape, mesh, profile)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            aparams, aopt, abatch)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        step = ts.make_prefill_step(cfg, mesh, profile)
        args = input_specs.prefill_specs(cfg, shape, mesh, profile)
        kwargs = {}
        if "vision_embeds" in args:
            kwargs["vision_embeds"] = args.pop("vision_embeds")
        lowered = jax.jit(step).lower(aparams, args["tokens"], **kwargs)
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        step = ts.make_decode_step(cfg, mesh, profile)
        args = input_specs.decode_specs(cfg, shape, mesh, profile)
        lowered = jax.jit(step, donate_argnums=(2,)).lower(
            aparams, args["tokens"], args["caches"], args["pos"])
        tokens = shape.global_batch  # one token per sequence per step

    meta = {
        "arch": arch,
        "shape": shape_name,
        "profile": profile,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "tokens_per_step": tokens,
        "params_total": pm.count_params(spec),
        "params_active": active_params(cfg),
    }
    return lowered, meta


def analysis_variant(arch: str, n_units: int, param_dtype: str | None = None):
    """Reduced-depth, fully-unrolled config for exact cost accounting.

    Returns (cfg, unit_multiplier): total = A + (B - A) * unit_multiplier
    where A/B are the n_units=1/2 measurements (see scan_utils docstring).
    """
    import dataclasses

    cfg = configs.get_config(arch)
    if param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    if cfg.n_cross_layers:  # unit = one (cross + group_self·self) group
        var = dataclasses.replace(
            cfg, n_cross_layers=n_units, n_layers=n_units * cfg.group_self,
            unroll_scans=True)
        return var, cfg.n_cross_layers - 1
    if cfg.first_dense:     # unit = one MoE layer (dense layer in the base)
        var = dataclasses.replace(
            cfg, n_layers=cfg.first_dense + (n_units - 1), unroll_scans=True)
        return var, cfg.n_layers - cfg.first_dense
    var = dataclasses.replace(cfg, n_layers=n_units, unroll_scans=True)
    return var, cfg.n_layers - 1


def _cost_of(cfg, shape_name: str, multi_pod: bool, profile: str = "default"):
    """Compile one (possibly analysis-variant) cell; return per-dev costs."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    run = RunConfig(model=cfg, shape=shape, profile=profile)
    spec = model_mod.model_spec(cfg)
    aparams = sharding.shard_abstract(spec, mesh, _param_dtype(cfg), profile)
    if shape.kind == "train":
        step = ts.make_train_step(cfg, run, mesh)
        aopt = optimizer.abstract_state(aparams, _moments_dtype(cfg))
        abatch = input_specs.batch_specs(cfg, shape, mesh, profile)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            aparams, aopt, abatch)
    elif shape.kind == "prefill":
        step = ts.make_prefill_step(cfg, mesh, profile)
        args = input_specs.prefill_specs(cfg, shape, mesh, profile)
        kwargs = {}
        if "vision_embeds" in args:
            kwargs["vision_embeds"] = args.pop("vision_embeds")
        lowered = jax.jit(step).lower(aparams, args["tokens"], **kwargs)
    else:
        step = ts.make_decode_step(cfg, mesh, profile)
        args = input_specs.decode_specs(cfg, shape, mesh, profile)
        lowered = jax.jit(step, donate_argnums=(2,)).lower(
            aparams, args["tokens"], args["caches"], args["pos"])
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = hlo_analysis.collective_bytes(text)
    mem = compiled.memory_analysis()
    fused = hlo_analysis.hbm_traffic_model(
        text,
        arg_bytes=getattr(mem, "argument_size_in_bytes", 0),
        out_bytes=getattr(mem, "output_size_in_bytes", 0))
    return {
        "flops_dev": float(cost.get("flops", 0.0)),
        "bytes_dev": float(cost.get("bytes accessed", 0.0)),
        "fused_bytes_dev": float(fused),
        "coll_operand_dev": coll.operand_bytes,
        "coll_wire_dev": coll.wire_bytes,
    }


def analysis_costs(arch: str, shape_name: str, multi_pod: bool,
                   profile: str = "default",
                   param_dtype: str | None = None) -> dict:
    """Layer-marginal extrapolation from unrolled 1-/2-unit compiles."""
    cfg_a, mult = analysis_variant(arch, 1, param_dtype)
    cfg_b, _ = analysis_variant(arch, 2, param_dtype)
    a = _cost_of(cfg_a, shape_name, multi_pod, profile)
    b = _cost_of(cfg_b, shape_name, multi_pod, profile)
    return {k: a[k] + (b[k] - a[k]) * mult for k in a}


def run_cell(arch: str, shape_name: str, multi_pod: bool, force=False,
             profile: str = "default") -> dict:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    ptag = "" if profile == "default" else f"_{profile}"
    out_path = RESULTS_DIR / f"dryrun_{arch}_{shape_name}_{mesh_tag}{ptag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, profile)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)  # scan-body counts (lower bound)

    # Exact cost accounting: while-loop bodies are counted once by XLA's
    # cost analysis, so FLOPs/bytes/collectives come from unrolled 1-/2-unit
    # analysis compiles, extrapolated linearly in depth. cost_analysis is
    # per-device -> scale to global for the roofline terms. The roofline
    # table is single-pod (per assignment); the multi-pod pass proves the
    # "pod" axis shards, so it skips the analysis compiles.
    ac = None if multi_pod else analysis_costs(arch, shape_name, multi_pod,
                                               profile)
    record = dict(meta)
    record.update(
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        scanbody_collective_by_op=coll.operand_by_op,
        scanbody_collective_counts=coll.count_by_op,
        memory_analysis={
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
    )
    model_fl = hlo_analysis.model_flops(
        meta["params_active"], meta["tokens_per_step"],
        "train" if meta["kind"] == "train" else "infer")
    record["model_flops"] = model_fl
    if ac is not None:
        flops = ac["flops_dev"] * meta["chips"]
        hbm_bytes = ac["fused_bytes_dev"] * meta["chips"]
        record.update(
            hlo_flops=flops,
            hlo_bytes=hbm_bytes,
            hlo_bytes_unfused=ac["bytes_dev"] * meta["chips"],
            collective_operand_bytes_per_dev=ac["coll_operand_dev"],
            collective_wire_bytes_per_dev=ac["coll_wire_dev"],
            useful_flops_frac=model_fl / flops if flops else 0.0,
            roofline=hlo_analysis.roofline_terms(
                flops, hbm_bytes, ac["coll_wire_dev"], meta["chips"]),
        )
    out_path.write_text(json.dumps(record, indent=2))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--profile", default="default")
    args = ap.parse_args()

    cells = []
    archs = sorted(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = configs.get_config(arch)
        names = shapes_for(cfg) if (args.all or not args.shape) else [args.shape]
        for sh in names:
            meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
            for mp in meshes:
                cells.append((arch, sh, mp))

    ok = fail = 0
    for arch, sh, mp in cells:
        tag = f"{arch} × {sh} × {'2x16x16' if mp else '16x16'}"
        try:
            rec = run_cell(arch, sh, mp, force=args.force, profile=args.profile)
            r = rec.get("roofline")
            if r:
                print(f"[dryrun] OK   {tag}: compile={rec['compile_s']}s "
                      f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                      f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}",
                      flush=True)
            else:
                print(f"[dryrun] OK   {tag}: compile={rec['compile_s']}s "
                      f"(multi-pod shard check)", flush=True)
            ok += 1
        except Exception:
            print(f"[dryrun] FAIL {tag}", flush=True)
            traceback.print_exc()
            fail += 1
    print(f"[dryrun] {ok} ok, {fail} failed", flush=True)
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
