"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 100 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt] [--morph-data]

On a real cluster this runs under `jax.distributed.initialize()` with the
production mesh; on a dev box --smoke uses the reduced config on the
local mesh. Fault tolerance (resume, preemption checkpoint, straggler
counters) comes from train/loop.py.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.configs import RunConfig, ShapeConfig
from repro.data import pipeline as data_pipeline
from repro.train import loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--morph-data", action="store_true",
                    help="Arabic char-LM stream with stemmer root labels")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", args.seq, args.batch, "train"),
        learning_rate=args.lr, lr_warmup=20, remat=args.remat,
        microbatches=args.microbatches)

    if args.morph_data:
        import numpy as np

        base = data_pipeline.morph_lm_batches(batch_words=2048, seq=args.seq)

        def batched():
            while True:
                rows = [next(base) for _ in range(args.batch)]
                yield {
                    "tokens": np.concatenate([r["tokens"] for r in rows]),
                    "labels": np.concatenate([r["labels"] for r in rows]),
                }

        data = batched()
    else:
        data = data_pipeline.synthetic_lm_batches(
            cfg.vocab, args.batch, args.seq, effective_vocab=64)

    def on_metrics(step, m):
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}",
                  flush=True)

    result = loop.fit(cfg, run, data, steps=args.steps,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      on_metrics=on_metrics)
    print(f"done: {result.steps_run} steps, final loss "
          f"{result.losses[-1]:.4f}, stragglers {result.straggler_events}, "
          f"resumed_from {result.resumed_from}")
    return result


if __name__ == "__main__":
    main()
