"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
while tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Small mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_data_mesh(n_dev: int | None = None):
    """1-D ("data",) mesh over the first ``n_dev`` devices (default: all).

    The serving tile shard path (dist.shard_batch / StemmerWorkload
    ``data_devices=N``) splits one [n_dev * block_b, 16] super-tile per
    launch along this axis.
    """
    avail = len(jax.devices())
    if n_dev is None:
        n_dev = avail
    if not 1 <= n_dev <= avail:
        raise ValueError(
            f"data mesh needs 1 <= n_dev <= {avail} devices, got {n_dev}")
    return jax.make_mesh((n_dev,), ("data",))
