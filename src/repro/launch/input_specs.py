"""ShapeDtypeStruct stand-ins for every model input, per (arch × shape).

These carry shardings, so ``jax.jit(step).lower(**input_specs(...))``
builds the full SPMD program without allocating a byte.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ModelConfig, ShapeConfig
from repro.dist import sharding
from repro.models import model as model_mod


def _sds(shape, dtype, axes, mesh, profile="default"):
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=sharding.array_sharding(axes, shape, mesh, profile))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, profile="default"):
    """Training-batch stand-ins: tokens/labels (+ vision embeddings)."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
    tok_axes = ("batch", None, None) if cfg.n_codebooks else ("batch", None)
    out = {
        "tokens": _sds(tok_shape, jnp.int32, tok_axes, mesh, profile),
        "labels": _sds(tok_shape, jnp.int32, tok_axes, mesh, profile),
    }
    if cfg.n_cross_layers:
        out["vision_embeds"] = _sds(
            (b, cfg.vision_seq, cfg.d_model), jnp.bfloat16,
            ("batch", None, None), mesh, profile)
    return out


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, profile="default"):
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
    tok_axes = ("batch", None, None) if cfg.n_codebooks else ("batch", None)
    args = {"tokens": _sds(tok_shape, jnp.int32, tok_axes, mesh, profile)}
    if cfg.n_cross_layers:
        args["vision_embeds"] = _sds(
            (b, cfg.vision_seq, cfg.d_model), jnp.bfloat16,
            ("batch", None, None), mesh, profile)
    return args


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, profile="default"):
    """Decode-step stand-ins: one new token + S-long caches + position."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks else (b, 1)
    tok_axes = ("batch", None, None) if cfg.n_codebooks else ("batch", None)

    cache_shapes = jax.eval_shape(
        lambda: model_mod.init_caches(cfg, b, cache_len=s))
    cache_axes = model_mod.cache_logical_axes(cfg)

    rules = sharding.rules_for(profile)

    def attach(sds_leaf, axes):
        return jax.ShapeDtypeStruct(
            sds_leaf.shape, sds_leaf.dtype,
            sharding=NamedSharding(
                mesh, sharding.resolve(axes, sds_leaf.shape, mesh, rules)))

    is_axes = lambda x: isinstance(x, tuple) and len(x) > 0 and all(
        isinstance(e, (str, type(None))) for e in x)
    flat_shapes, treedef = jax.tree.flatten(cache_shapes)
    flat_axes = jax.tree.flatten(cache_axes, is_leaf=is_axes)[0]
    assert len(flat_shapes) == len(flat_axes), "cache axes/shape tree mismatch"
    caches = jax.tree.unflatten(
        treedef, [attach(s, a) for s, a in zip(flat_shapes, flat_axes)])

    return {
        "tokens": _sds(tok_shape, jnp.int32, tok_axes, mesh, profile),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
