"""Serving launcher: continuous-batching engine over a smoke model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as model_mod
from repro.models import params as pm
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.smoke_config(configs.get_config(args.arch))
    params = pm.init_params(model_mod.model_spec(cfg), jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch, cache_len=256)

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [
        eng.submit(rng.integers(0, cfg.vocab, args.prompt_len),
                   max_new=args.max_new)
        for _ in range(args.requests)
    ]
    ticks = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(eng.result(r).tokens_out) for r in rids)
    print(f"served {args.requests} requests / {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s, {ticks} ticks)")
    for rid in rids[:4]:
        print(f"  req {rid}: {eng.result(rid).tokens_out}")


if __name__ == "__main__":
    main()
