"""Serving launcher: workload-agnostic continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --workload lm --arch llama3-8b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --workload stemmer --requests 16
  PYTHONPATH=src python -m repro.launch.serve --workload text --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as model_mod
from repro.models import params as pm
from repro.serve import (DegradationPolicy, DictStore, Engine, Journal,
                         LMDecodeWorkload, StemmerWorkload,
                         TextAnalysisWorkload)


def _engine_kw(args) -> dict:
    """Engine admission-control + crash-safety kwargs shared by all
    three workloads (the journal/policy flags are validated in main()
    before any engine is constructed)."""
    kw = dict(queue_cap=args.queue_cap or None, on_full=args.on_full)
    if getattr(args, "journal", None):
        kw["journal"] = Journal(args.journal)
    if getattr(args, "degrade", "off") == "on":
        kw["policy"] = DegradationPolicy()
    return kw


def _deadline_s(args) -> float | None:
    return args.deadline_ms / 1000.0 if args.deadline_ms else None


def _retry_kw(args) -> dict:
    """StemmerWorkload/TextAnalysisWorkload retry kwargs (lm has none)."""
    kw = {} if args.max_retries is None else dict(
        max_retries=args.max_retries)
    if args.watchdog_ms:
        kw["watchdog_s"] = args.watchdog_ms / 1000.0
    return kw


def _report_events(eng) -> None:
    """Structured incident stream (Engine.events): the supported way to
    see retries, stalls, device losses and ladder transitions."""
    events = eng.events()
    if not events:
        return
    counts: dict[str, int] = {}
    for ev in events:
        counts[ev.kind] = counts.get(ev.kind, 0) + 1
    print("  events: " + ", ".join(f"{k} x{n}"
                                   for k, n in sorted(counts.items())))
    for ev in events:
        if ev.kind in ("degrade", "upshift"):
            print(f"    {ev.kind}: {ev.data['from']} -> {ev.data['to']}"
                  f" ({ev.data['reason']})")


def _report_failures(eng, rids) -> str:
    failed = [eng.result(r) for r in rids]
    failed = [r for r in failed if r is not None and r.failure is not None]
    for req in failed[:4]:
        print(f"  req {req.rid} FAILED: {req.failure.code}"
              f" ({req.failure.detail})")
    return f", {len(failed)} failed, {eng.shed} shed" if failed else ""


def required_cache_len(prompt_len: int, max_new: int) -> int:
    """KV positions a request writes: prompt_len prefill steps plus
    max_new - 1 decode steps (the last emitted token is never fed back)."""
    return prompt_len + max_new - 1


def serve_lm(args) -> None:
    need = required_cache_len(args.prompt_len, args.max_new)
    cache_len = args.cache_len if args.cache_len else need
    if cache_len < need:
        raise SystemExit(
            f"--cache-len {cache_len} would overflow: prompt_len"
            f" {args.prompt_len} + max_new {args.max_new} needs >= {need}"
            " cache positions")

    cfg = configs.smoke_config(configs.get_config(args.arch))
    params = pm.init_params(model_mod.model_spec(cfg), jax.random.key(0))
    eng = Engine(LMDecodeWorkload(cfg, params, max_batch=args.max_batch,
                                  cache_len=cache_len), **_engine_kw(args))

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [
        eng.submit(rng.integers(0, cfg.vocab, args.prompt_len),
                   max_new=args.max_new, deadline_s=_deadline_s(args))
        for _ in range(args.requests)
    ]
    rep = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(eng.result(r).tokens_out) for r in rids)
    print(f"served {args.requests} requests / {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s, {rep.ticks} ticks, "
          f"cache_len {cache_len}{_report_failures(eng, rids)})")
    for rid in rids[:4]:
        print(f"  req {rid}: {eng.result(rid).tokens_out}")


def serve_stemmer(args) -> None:
    from repro.core import corpus, stemmer

    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    # the store pins residency AND the streamed tile/boundary tables per
    # publish, so hot swaps replay the serving trace (DESIGN.md §6)
    store = DictStore(stemmer.RootDictArrays.from_rootdict(d),
                      dict_block_r=args.dict_block_r)
    eng = Engine(StemmerWorkload(store, block_b=args.block_b,
                                 dict_block_r=args.dict_block_r,
                                 num_buffers=args.num_buffers,
                                 skip_index=not args.full_sweep,
                                 max_inflight=args.inflight,
                                 data_devices=args.devices,
                                 megabatch_tiles=args.megabatch,
                                 persistent=args.persistent,
                                 **_retry_kw(args)), **_engine_kw(args))

    wpr = args.words_per_request
    words, _, _ = corpus.build_corpus(n_words=args.requests * wpr, seed=1)
    enc = corpus.encode_corpus(words)

    t0 = time.time()
    rids = [eng.submit(enc[i * wpr:(i + 1) * wpr],
                       deadline_s=_deadline_s(args))
            for i in range(args.requests)]
    rep = eng.run_until_drained()
    dt = time.time() - t0
    n_words = args.requests * wpr
    print(f"served {args.requests} word-batch requests / {n_words} words in "
          f"{dt:.2f}s ({n_words / dt:.1f} Wps, {rep.ticks} ticks, "
          f"{eng.workload.ticks_launched} launches, dict v{store.version}, "
          f"super-tile {args.devices}x{args.block_b}, "
          f"megabatch {args.megabatch}"
          f"{', persistent' if args.persistent else ''}, "
          f"inflight {args.inflight}{_report_failures(eng, rids)})")
    _report_events(eng)
    for rid in rids[:2]:
        req = eng.result(rid)
        if req.failure is None:
            print(f"  req {rid}: {req.n_words} roots,"
                  f" dict v{req.dict_version}")


def build_documents(n_docs: int, words_per_doc: int, seed: int = 1):
    """Synthesise raw Arabic documents from the conjugated corpus: words
    joined with spaces, an Arabic comma sprinkled every ~8 words, and a
    rotating clitic attached to every third word so the front end's
    stripping path is exercised end to end."""
    from repro.core import corpus

    words, _, _ = corpus.build_corpus(n_words=n_docs * words_per_doc,
                                      seed=seed)
    pro = ("وال", "ب", "ف", "لل", "ك")
    docs = []
    for i in range(n_docs):
        chunk = words[i * words_per_doc:(i + 1) * words_per_doc]
        toks = [pro[j % len(pro)] + w if j % 3 == 0 else w
                for j, w in enumerate(chunk)]
        toks = [t + "،" if j % 8 == 7 else t for j, t in enumerate(toks)]
        docs.append(" ".join(toks))
    return docs


def serve_text(args) -> None:
    from repro.core import corpus, stemmer

    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    store = DictStore(stemmer.RootDictArrays.from_rootdict(d),
                      dict_block_r=args.dict_block_r)
    eng = Engine(TextAnalysisWorkload(store, block_b=args.block_b,
                                      char_block=args.char_block,
                                      frontend=args.frontend,
                                      dict_block_r=args.dict_block_r,
                                      num_buffers=args.num_buffers,
                                      skip_index=not args.full_sweep,
                                      max_inflight=args.inflight,
                                      data_devices=args.devices,
                                      megabatch_tiles=args.megabatch,
                                      persistent=args.persistent,
                                      **_retry_kw(args)), **_engine_kw(args))

    docs = build_documents(args.requests, args.words_per_request)
    n_bytes = sum(len(doc.encode("utf-8")) for doc in docs)
    t0 = time.time()
    rids = [eng.submit(doc, deadline_s=_deadline_s(args)) for doc in docs]
    rep = eng.run_until_drained()
    dt = time.time() - t0
    n_words = sum(eng.result(r).n_words for r in rids)
    print(f"served {args.requests} documents / {n_bytes} bytes /"
          f" {n_words} words in {dt:.2f}s ({n_bytes / dt:.0f} B/s,"
          f" {n_words / dt:.1f} Wps, {rep.ticks} ticks,"
          f" {eng.workload.ticks_launched} launches,"
          f" frontend {args.frontend}, megabatch {args.megabatch},"
          f" inflight {args.inflight}{_report_failures(eng, rids)})")
    _report_events(eng)
    for rid in rids[:2]:
        req = eng.result(rid)
        if req.failure is not None:
            continue
        root, src, span = req.analyses()[0][0]
        print(f"  req {rid}: {req.n_words} tokens, first root {root!r}"
              f" (src {src}, bytes {span})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "stemmer", "text"),
                    default="lm")
    ap.add_argument("--requests", type=int, default=8)
    # lm knobs
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="KV cache positions per slot (default: derived"
                         " from --prompt-len + --max-new; explicit values"
                         " too small for that are rejected)")
    # stemmer knobs
    ap.add_argument("--words-per-request", type=int, default=64)
    ap.add_argument("--block-b", type=int, default=256)
    ap.add_argument("--inflight", type=int, default=2,
                    help="dispatch ring depth: outstanding megakernel"
                         " launches (1 = synchronous tick, overlap off)")
    ap.add_argument("--devices", type=int, default=1,
                    help="data devices per super-tile: each launch is a"
                         " [devices * block_b, 16] tile shard_map'd over"
                         " a ('data',) mesh (dist.shard_batch)")
    ap.add_argument("--dict-block-r", type=int, default=8,
                    help="streamed dictionary tile height in 128-lane"
                         " rows; also pins the publish-time tile stream")
    ap.add_argument("--num-buffers", type=int, default=2,
                    help="streamed-path DMA ladder depth (1 = no"
                         " overlap, 2 = double buffering, up to 4)")
    ap.add_argument("--full-sweep", action="store_true",
                    help="disable the tile-visit skip index (sweep every"
                         " dictionary tile; the skip-off baseline)")
    ap.add_argument("--megabatch", type=int, default=1,
                    help="super-tiles coalesced per launch: the grid's"
                         " batch axis spans the whole megabatch, so one"
                         " dispatch retires up to this many queue tiles"
                         " (1 = the per-tile baseline)")
    ap.add_argument("--persistent", action="store_true",
                    help="persistent serving kernel: ONE launch loops a"
                         " device-side work-descriptor ring over the"
                         " megabatch (single-device only)")
    # text knobs
    ap.add_argument("--char-block", type=int, default=2048,
                    help="codepoint-tile bucket for the text front end"
                         " (requests round up to a pow2 multiple)")
    ap.add_argument("--frontend", choices=("kernel", "reference", "host"),
                    default="kernel",
                    help="text front end: Pallas kernel, pure-jnp"
                         " reference, or the python oracle")
    # robustness knobs (DESIGN.md §11)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline in milliseconds; expired"
                         " requests finish with FailureInfo code"
                         " 'deadline' (0 = no deadline)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="launch retries before bisect/quarantine"
                         " (stemmer/text only; 0 = strict fail-fast,"
                         " default 2)")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="admission-control bound on queued requests"
                         " (0 = unbounded)")
    ap.add_argument("--on-full", choices=Engine.ON_FULL, default="raise",
                    help="full-queue policy: raise QueueFull, shed the"
                         " new request (FailureInfo 'shed'), or block"
                         " until a slot frees")
    # crash safety + degraded modes (DESIGN.md §12)
    ap.add_argument("--journal", default="", metavar="PATH",
                    help="write-ahead request journal: every accepted"
                         " request is durable before it is served, so a"
                         " killed server warm-restarts via"
                         " Engine.recover(PATH) with zero lost requests")
    ap.add_argument("--watchdog-ms", type=float, default=0.0,
                    help="persistent-kernel stall watchdog: a launch"
                         " whose completion flags stop advancing for"
                         " this long is abandoned, its retired-prefix"
                         " salvaged and the rest re-dispatched down the"
                         " megabatch path (requires --persistent;"
                         " 0 = off)")
    ap.add_argument("--degrade", choices=("on", "off"), default="off",
                    help="graceful-degradation ladder: under sustained"
                         " faults or queue pressure the serving mode"
                         " downshifts persistent -> megabatch ->"
                         " per-tile -> streamed-dict -> fewer devices,"
                         " and upshifts when healthy (stemmer/text"
                         " only)")
    args = ap.parse_args()

    if args.deadline_ms < 0:
        ap.error("--deadline-ms must be >= 0")
    if args.queue_cap < 0:
        ap.error("--queue-cap must be >= 0")
    if args.max_retries is not None and args.max_retries < 0:
        ap.error("--max-retries must be >= 0")
    if args.on_full != "raise" and not args.queue_cap:
        ap.error(f"--on-full {args.on_full} needs --queue-cap > 0"
                 " (an unbounded queue is never full)")
    if args.workload == "lm" and args.max_retries is not None:
        ap.error("--max-retries applies to the stemmer/text workloads"
                 " (the LM decode loop has no launch retry path)")
    # cross-validate the crash-safety flags BEFORE any engine exists, so
    # an invalid combination never half-constructs serving state
    if args.watchdog_ms < 0:
        ap.error("--watchdog-ms must be >= 0")
    if args.watchdog_ms and not args.persistent:
        ap.error("--watchdog-ms guards the persistent descriptor ring;"
                 " it requires --persistent")
    if args.watchdog_ms and args.workload == "lm":
        ap.error("--watchdog-ms applies to the stemmer/text workloads")
    if args.degrade == "on" and args.workload == "lm":
        ap.error("--degrade applies to the stemmer/text workloads (the"
                 " LM decode loop has no mode ladder)")

    if args.workload == "stemmer":
        serve_stemmer(args)
    elif args.workload == "text":
        serve_text(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
