"""Decoder blocks: attention (dense/MoE/MLA), Mamba, Hymba (parallel
attn+SSM heads), cross-attention (VLM). Each block exposes spec / full /
prefill / decode entry points with a uniform cache pytree so the model
can scan over stacked layers in every mode.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers, mamba, mla, moe


class BlockCache(NamedTuple):
    """Uniform per-layer cache; unused fields are () placeholders."""

    kv: Any = ()      # attention.KVCache | mla.MLACache
    ssm: Any = ()     # mamba.MambaCache


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------
def block_spec(cfg, *, moe_layer: bool | None = None):
    if moe_layer is None:
        moe_layer = cfg.is_moe
    s = {"norm1": layers.rmsnorm_spec(cfg.d_model)}
    if cfg.block == "mamba":
        s["mamba"] = mamba.mamba_spec(cfg)
        return s  # mamba blocks in Falcon-Mamba have no separate FFN
    if cfg.block == "hymba":
        s["attn"] = attn.attn_spec(cfg)
        s["mamba"] = mamba.mamba_spec(cfg)
        s["norm_a"] = layers.rmsnorm_spec(cfg.d_model)
        s["norm_m"] = layers.rmsnorm_spec(cfg.d_model)
    elif cfg.attn_impl == "mla":
        s["attn"] = mla.mla_spec(cfg)
    else:
        s["attn"] = attn.attn_spec(cfg)
    s["norm2"] = layers.rmsnorm_spec(cfg.d_model)
    s["ffn"] = moe.moe_spec(cfg) if moe_layer else layers.ffn_spec(cfg.d_model, cfg.d_ff, cfg.ffn)
    s["_moe"] = moe_layer  # static marker, stripped before init
    return s


def cross_block_spec(cfg):
    return {
        "norm1": layers.rmsnorm_spec(cfg.d_model),
        "attn": attn.cross_attn_spec(cfg),
        "norm2": layers.rmsnorm_spec(cfg.d_model),
        "ffn": layers.ffn_spec(cfg.d_model, cfg.d_ff, cfg.ffn),
    }


def strip_markers(tree):
    """Remove static `_moe` markers so the tree is a pure param tree."""
    if isinstance(tree, dict):
        return {k: strip_markers(v) for k, v in tree.items() if k != "_moe"}
    return tree


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------
def _mixer_full(p, h, cfg, mode, cache, positions, pos, dt, cst=None):
    """Token mixer (attention / mamba / hymba) in any mode."""
    if cfg.block == "mamba":
        if mode == "decode":
            return mamba.mamba_decode(p["mamba"], h, cfg, cache.ssm, dt=dt)
        return mamba.mamba_block(p["mamba"], h, cfg, dt=dt, constrain=cst)

    if cfg.block == "hymba":
        if mode == "decode":
            ya, kvc = attn.decode_attention(p["attn"], h, cfg, cache.kv,
                                            pos=pos, dt=dt, constrain=cst)
            ym, ssc = mamba.mamba_decode(p["mamba"], h, cfg, cache.ssm, dt=dt)
        else:
            if mode == "prefill":
                ya, kvc = attn.prefill_attention(
                    p["attn"], h, cfg, positions=positions,
                    cache_len=_cache_len(cfg, h.shape[1]), dt=dt, constrain=cst)
            else:
                ya = attn.self_attention(p["attn"], h, cfg, positions=positions,
                                         chunk_q=_chunk_q(h.shape[1]), dt=dt,
                                         constrain=cst)
                kvc = ()
            ym, ssc = mamba.mamba_block(p["mamba"], h, cfg, dt=dt, constrain=cst)
        ya = layers.rmsnorm(p["norm_a"], ya, cfg.rms_eps)
        ym = layers.rmsnorm(p["norm_m"], ym, cfg.rms_eps)
        return 0.5 * (ya + ym), (kvc, ssc)

    if cfg.attn_impl == "mla":
        if mode == "decode":
            return mla.mla_decode(p["attn"], h, cfg, cache.kv, pos=pos, dt=dt,
                                  constrain=cst)
        if mode == "prefill":
            return mla.mla_attention(p["attn"], h, cfg, positions=positions,
                                     dt=dt, return_cache=True, constrain=cst)
        return mla.mla_attention(p["attn"], h, cfg, positions=positions, dt=dt,
                                 constrain=cst), ()

    if mode == "decode":
        return attn.decode_attention(p["attn"], h, cfg, cache.kv, pos=pos,
                                     dt=dt, constrain=cst)
    if mode == "prefill":
        return attn.prefill_attention(p["attn"], h, cfg, positions=positions,
                                      cache_len=_cache_len(cfg, h.shape[1]),
                                      dt=dt, constrain=cst)
    return attn.self_attention(p["attn"], h, cfg, positions=positions,
                               chunk_q=_chunk_q(h.shape[1]), dt=dt,
                               constrain=cst), ()


def _cache_len(cfg, seq: int) -> int:
    return min(seq, cfg.sliding_window) if cfg.sliding_window else seq


def _chunk_q(seq: int) -> int:
    """Query-block size: keeps the fp32 score matrix O(chunk × seq) — at
    4k+ sequences unchunked scores dominate per-device temp memory."""
    if seq >= 8192 and seq % 1024 == 0:
        return 1024
    if seq >= 4096 and seq % 512 == 0:
        return 512
    return 0


def block(p, h, cfg, *, mode="full", cache=BlockCache(), positions=None,
          pos=None, moe_layer=None, constrain=None, dt=jnp.bfloat16):
    """One decoder block. Returns (h, new_cache, aux_loss)."""
    if moe_layer is None:
        moe_layer = cfg.is_moe and cfg.block == "attn"
    aux = jnp.zeros((), jnp.float32)

    hn = layers.rmsnorm(p["norm1"], h, cfg.rms_eps)
    mixer_out = _mixer_full(p, hn, cfg, mode, cache, positions, pos, dt,
                            cst=constrain)
    y, new_cache_raw = mixer_out
    h = h + y

    if mode == "full":  # training: never materialise stacked caches
        new_cache_raw = ((), ()) if cfg.block == "hymba" else ()

    if cfg.block == "mamba":
        new_cache = BlockCache(kv=(), ssm=new_cache_raw)
        return h, new_cache, aux

    if cfg.block == "hymba":
        kvc, ssc = new_cache_raw if isinstance(new_cache_raw, tuple) else ((), ())
        new_cache = BlockCache(kv=kvc, ssm=ssc)
    else:
        new_cache = BlockCache(kv=new_cache_raw, ssm=())

    hn = layers.rmsnorm(p["norm2"], h, cfg.rms_eps)
    if moe_layer:
        y, aux = moe.moe_ffn(p["ffn"], hn, cfg, constrain=constrain, dt=dt)
    else:
        y = layers.ffn(p["ffn"], hn, cfg.ffn, compute_dtype=dt)
    h = h + y
    return h, new_cache, aux


def cross_block(p, h, enc, cfg, dt=jnp.bfloat16):
    """Cross-attention block (VLM): attends to vision embeddings."""
    hn = layers.rmsnorm(p["norm1"], h, cfg.rms_eps)
    h = h + attn.cross_attention(p["attn"], hn, enc, cfg, dt=dt)
    hn = layers.rmsnorm(p["norm2"], h, cfg.rms_eps)
    h = h + layers.ffn(p["ffn"], hn, cfg.ffn, compute_dtype=dt)
    return h
