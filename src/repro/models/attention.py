"""Attention: GQA/MQA/MHA self-attention (train / prefill / decode),
sliding windows, cross-attention, ring-buffer KV caches.

Implementation notes (memory-driven, see EXPERIMENTS §Perf):
  * masks are ADDITIVE f32 biases computed from iotas, never boolean
    `where` operands — a `select` saves its predicate for the backward
    pass (O(scores) bools per q-block stacked across scans), an `add`
    saves nothing;
  * q-block chunking keeps the fp32 score matrix O(chunk × seq);
  * q/k/v carry explicit sharding constraints so the SPMD partitioner
    cannot re-replicate the batch when heads don't divide the model axis;
  * decode KV caches shard their sequence dim on the model axis
    (FlashDecoding-style split-KV): each shard computes a partial softmax
    and XLA stitches the global softmax with small stat all-reduces.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.scan_utils import scan as _scan

from repro.models import layers

NEG = -1e30


def attn_spec(cfg):
    from repro.models.params import ParamSpec

    hd = cfg.head_dim
    s = {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, hd), ("fsdp", "model", None)),
        "wk": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("fsdp", "model", None)),
        "wv": ParamSpec((cfg.d_model, cfg.n_kv_heads, hd), ("fsdp", "model", None)),
        "wo": ParamSpec((cfg.n_heads, hd, cfg.d_model), ("model", None, "fsdp")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((cfg.n_heads, hd), ("model", None), init="zeros")
        s["bk"] = ParamSpec((cfg.n_kv_heads, hd), ("model", None), init="zeros")
        s["bv"] = ParamSpec((cfg.n_kv_heads, hd), ("model", None), init="zeros")
    return s


def cross_attn_spec(cfg):
    return attn_spec(cfg)


class KVCache(NamedTuple):
    """k/v: [B, S_cache, n_kv, head_dim]; ring buffer iff S_cache < seq."""

    k: jnp.ndarray
    v: jnp.ndarray


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(token, head) scales — halves the decode
    memory floor vs bf16 (KIVI/KVQuant-style, symmetric per-vector).

    k/v: int8[B, S, KV, hd]; k_scale/v_scale: f32[B, S, KV, 1]."""

    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: jnp.ndarray
    v_scale: jnp.ndarray


def quantise_kv(x: jnp.ndarray):
    """bf16 [..., hd] -> (int8 [..., hd], f32 scale [..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantise_kv(q: jnp.ndarray, scale: jnp.ndarray, dt) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dt)


def _cst(constrain, x, axes):
    return constrain(x, axes) if constrain is not None else x


def _qkv(p, x, cfg, dt, constrain=None):
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dnh->btnh", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dnh->btnh", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = _cst(constrain, q, ("batch", None, "heads", None))
    k = _cst(constrain, k, ("batch", None, "heads", None))
    v = _cst(constrain, v, ("batch", None, "heads", None))
    return q, k, v


def _sdpa(q, k, v, bias, n_rep: int):
    """q [B,Tq,H,hd]; k/v [B,S,KV,hd]; bias additive f32, broadcastable to
    [B,KV,rep,Tq,S] (or None)."""
    b, tq, h, hd = q.shape
    kv = k.shape[2]
    q = q.reshape(b, tq, kv, n_rep, hd)
    scores = jnp.einsum("btkrh,bskh->bkrts", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if bias is not None:
        scores = scores + bias
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrts,bskh->btkrh", w, v)
    return out.reshape(b, tq, h, hd)


def _causal_bias(tq: int, s: int, offset, window: int):
    """f32[1,1,1,tq,s] additive causal(+window) bias from iotas."""
    qpos = offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG).astype(jnp.float32)[None, None, None]


def _attend_chunked(q, k, v, cfg, n_rep, chunk_q):
    b, t = q.shape[0], q.shape[1]
    if chunk_q and t % chunk_q == 0 and t > chunk_q:
        nblk = t // chunk_q

        # The block body is checkpointed: without it the scan stacks the
        # softmax residuals of every block (a full seq x seq fp32 score
        # matrix — exactly what chunking is meant to avoid). Recomputing
        # each block's scores in the backward pass is the FlashAttention
        # trade: ~1 extra flop-pass for O(chunk*seq) memory.
        @jax.checkpoint
        def body_inner(qb, i):
            bias = _causal_bias(chunk_q, t, i * chunk_q, cfg.sliding_window)
            return _sdpa(qb, k, v, bias, n_rep)

        def body(_, qb_i):
            return None, body_inner(*qb_i)

        qs = jnp.moveaxis(
            q.reshape(b, nblk, chunk_q, cfg.n_heads, cfg.head_dim), 1, 0)
        _, outs = _scan(body, None, (qs, jnp.arange(nblk)),
                        unroll=getattr(cfg, 'unroll_scans', False))
        return jnp.moveaxis(outs, 0, 1).reshape(b, t, cfg.n_heads, cfg.head_dim)
    bias = _causal_bias(t, t, 0, cfg.sliding_window)
    return _sdpa(q, k, v, bias, n_rep)


def self_attention(p, x, cfg, *, positions, chunk_q: int = 0, dt=jnp.bfloat16,
                   constrain=None):
    """Full-sequence causal attention (train)."""
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(p, x, cfg, dt, constrain)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = _cst(constrain, q, ("batch", None, "heads", None))
    k = _cst(constrain, k, ("batch", None, "heads", None))
    out = _attend_chunked(q, k, v, cfg, n_rep, chunk_q)
    return jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))


def prefill_attention(p, x, cfg, *, positions, cache_len: int, dt=jnp.bfloat16,
                      constrain=None):
    """Causal attention that also returns the KV cache (ring-truncated)."""
    t = x.shape[1]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(p, x, cfg, dt, constrain)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = _cst(constrain, q, ("batch", None, "heads", None))
    k = _cst(constrain, k, ("batch", None, "heads", None))
    chunk = 1024 if (t > 4096 and t % 1024 == 0) else 0
    out = _attend_chunked(q, k, v, cfg, n_rep, chunk)
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
    if cache_len < t:  # ring buffer keeps the last cache_len positions
        k, v = k[:, -cache_len:], v[:, -cache_len:]
    k = _cst(constrain, k, ("batch", "kv_seq", None, None))
    v = _cst(constrain, v, ("batch", "kv_seq", None, None))
    if getattr(cfg, "kv_quant", False):
        kq, ks = quantise_kv(k)
        vq, vs = quantise_kv(v)
        return y, QuantKVCache(k=kq, v=vq, k_scale=ks, v_scale=vs)
    return y, KVCache(k=k, v=v)


def decode_attention(p, x, cfg, cache, *, pos, dt=jnp.bfloat16,
                     constrain=None):
    """Single-token decode against a (possibly ring, possibly int8) cache.

    x [B,1,d]; pos scalar int32 — global position of the new token.
    """
    s_cache = cache.k.shape[1]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(p, x, cfg, dt, constrain)
    posv = pos[None] if pos.ndim == 0 else pos
    q = layers.apply_rope(q, posv, cfg.rope_theta)
    k = layers.apply_rope(k, posv, cfg.rope_theta)

    slot = pos % s_cache
    quant = isinstance(cache, QuantKVCache)
    if quant:
        kq, ks = quantise_kv(k)
        vq, vs = quantise_kv(v)
        cache = QuantKVCache(
            k=jax.lax.dynamic_update_slice_in_dim(cache.k, kq, slot, axis=1),
            v=jax.lax.dynamic_update_slice_in_dim(cache.v, vq, slot, axis=1),
            k_scale=jax.lax.dynamic_update_slice_in_dim(
                cache.k_scale, ks, slot, axis=1),
            v_scale=jax.lax.dynamic_update_slice_in_dim(
                cache.v_scale, vs, slot, axis=1))
        new_k = dequantise_kv(cache.k, cache.k_scale, dt)
        new_v = dequantise_kv(cache.v, cache.v_scale, dt)
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_k = _cst(constrain, new_k, ("batch", "kv_seq", None, None))
    new_v = _cst(constrain, new_v, ("batch", "kv_seq", None, None))

    # valid cache slots: ring position maps slot -> global position
    idx = jnp.arange(s_cache)
    kpos = jnp.where(idx <= slot, pos - slot + idx, pos - slot - s_cache + idx)
    ok = (kpos >= 0) & (kpos <= pos)
    if cfg.sliding_window:
        ok &= kpos > pos - cfg.sliding_window
    bias = jnp.where(ok, 0.0, NEG).astype(jnp.float32)[None, None, None, None]

    out = _sdpa(q, new_k, new_v, bias, n_rep)
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
    return y, (cache if quant else KVCache(k=new_k, v=new_v))


def cross_attention(p, x, enc, cfg, dt=jnp.bfloat16, constrain=None):
    """x [B,T,d] attends to encoder states enc [B,S,d] (no mask, no rope)."""
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dnh->bsnh", enc, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", enc, p["wv"].astype(dt))
    q = _cst(constrain, q, ("batch", None, "heads", None))
    out = _sdpa(q, k, v, None, n_rep)
    return jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
