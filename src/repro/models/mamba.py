"""Mamba-1 selective SSM block: chunked associative-scan training path +
O(1)-state decode path.

TPU adaptation (see DESIGN.md): the CUDA selective-scan kernel fuses the
recurrence in SRAM; on TPU we chunk the sequence (cfg.ssm_chunk) and run a
`jax.lax.associative_scan` *within* chunks (log-depth, VPU friendly) with a
`lax.scan` carrying the [B, d_inner, N] state *across* chunks — the
intermediate [B, chunk, d_inner, N] tensor is what bounds VMEM/HBM traffic
instead of the full [B, T, d_inner, N].

Tensor parallelism: d_inner is Megatron-style column/row parallel
(in_proj column, out_proj row); the recurrence is elementwise over
d_inner so shards never communicate inside the scan.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec
from repro.models.scan_utils import scan as _scan


def mamba_spec(cfg):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    return {
        "in_proj": ParamSpec((d, 2 * di), ("fsdp", "model")),
        "conv_w": ParamSpec((cfg.d_conv, di), (None, "model"), scale=0.2),
        "conv_b": ParamSpec((di,), ("model",), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), ("model", None)),
        "dt_proj": ParamSpec((r, di), (None, "model"), scale=0.1),
        "dt_bias": ParamSpec((di,), ("model",), init="zeros"),
        "a_log": ParamSpec((di, n), ("model", None), init="ones"),
        "d_skip": ParamSpec((di,), ("model",), init="ones"),
        "out_proj": ParamSpec((di, d), ("model", "fsdp")),
    }


class MambaCache(NamedTuple):
    conv: jnp.ndarray  # [B, d_conv-1, d_inner] trailing conv inputs
    ssm: jnp.ndarray   # [B, d_inner, N] recurrent state (fp32)


def init_cache(cfg, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    )


def _ssm_inputs(p, xc, cfg, dt):
    """xc [B,T,di] (post-conv, post-silu) -> (delta, B_ssm, C_ssm)."""
    n, r = cfg.ssm_state, cfg.dt_rank_
    proj = xc @ p["x_proj"].astype(dt)
    dt_in, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        dt_in @ p["dt_proj"].astype(dt) + p["dt_bias"].astype(dt)
    ).astype(jnp.float32)
    return delta, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def _causal_conv(p, x, cfg, dt, history=None):
    """Depthwise causal conv1d. history [B, d_conv-1, di] or None (zeros)."""
    k = cfg.d_conv
    if history is None:
        history = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)
    w = p["conv_w"].astype(dt)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return out + p["conv_b"].astype(dt), xp[:, -(k - 1) :]


def _scan_chunks(a, bx, h0, chunk: int, unroll: bool = False):
    """h_t = a_t * h_{t-1} + bx_t over T, chunked.

    a, bx: [B, T, di, N] fp32; h0 [B, di, N]. Returns (h_all [B,T,di,N], h_T).
    """
    b, t, di, n = a.shape
    nc = t // chunk
    a_c = a.reshape(b, nc, chunk, di, n).swapaxes(0, 1)
    bx_c = bx.reshape(b, nc, chunk, di, n).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, inputs):
        ac, bc = inputs
        ca, cb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = ca * h[:, None] + cb
        return h_all[:, -1], h_all

    h_last, h_chunks = _scan(step, h0, (a_c, bx_c), unroll=unroll)
    return h_chunks.swapaxes(0, 1).reshape(b, t, di, n), h_last


def mamba_block(p, x, cfg, *, dt=jnp.bfloat16, cache: MambaCache | None = None,
                constrain=None):
    """Full-sequence Mamba block. Returns (y, new_cache)."""
    cst = constrain or (lambda v, a: v)
    b, t, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"].astype(dt)
    xz = cst(xz, ("batch", None, "model"))
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_hist = _causal_conv(p, x_in, cfg, dt,
                                 cache.conv if cache is not None else None)
    xc = jax.nn.silu(xc)

    delta, b_ssm, c_ssm = _ssm_inputs(p, xc, cfg, dt)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # [di, N]
    abar = jnp.exp(delta[..., None] * a)                         # [B,T,di,N]
    bx = (delta * xc.astype(jnp.float32))[..., None] * b_ssm[:, :, None, :]

    h0 = cache.ssm if cache is not None else jnp.zeros((b, di, n), jnp.float32)
    chunk = min(cfg.ssm_chunk, t)
    if t % chunk:
        chunk = t
    h_all, h_last = _scan_chunks(abar, bx, h0, chunk,
                                 unroll=getattr(cfg, 'unroll_scans', False))

    y = jnp.einsum("btdn,btn->btd", h_all, c_ssm).astype(dt)
    y = y + xc * p["d_skip"].astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    return out, MambaCache(conv=conv_hist, ssm=h_last)


def mamba_decode(p, x, cfg, cache: MambaCache, *, dt=jnp.bfloat16):
    """Single-token step: O(d_inner * N) state update, no scan."""
    b = x.shape[0]
    xz = x @ p["in_proj"].astype(dt)
    x_in, z = jnp.split(xz, 2, axis=-1)                          # [B,1,di]
    xc, conv_hist = _causal_conv(p, x_in, cfg, dt, cache.conv)
    xc = jax.nn.silu(xc)

    delta, b_ssm, c_ssm = _ssm_inputs(p, xc, cfg, dt)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    abar = jnp.exp(delta[:, 0, :, None] * a)                     # [B,di,N]
    bx = (delta[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b_ssm[:, 0, None, :]
    h = abar * cache.ssm + bx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])[:, None].astype(dt)
    y = y + xc * p["d_skip"].astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    return out, MambaCache(conv=conv_hist, ssm=h)
