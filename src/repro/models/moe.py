"""Mixture-of-Experts FFN: top-k routing, capacity-bounded scatter
dispatch, expert parallelism over the `model` mesh axis.

Dispatch strategy (TPU/pjit-native): token->slot destinations are computed
with a cumsum over the routing one-hot, then tokens are scattered into an
[E, C, d] buffer sharded (experts->model, capacity->data). XLA SPMD turns
the resharding scatter/gather into all-to-alls. FLOP cost is
O(T * top_k * cf * d * ff) — the *active* FLOPs — unlike one-hot einsum
dispatch which is quadratic in tokens. Overflowing tokens are dropped
(standard capacity-factor semantics); the router aux loss keeps load
balanced so drops stay rare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def moe_spec(cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    s = {
        "router": ParamSpec((d, e), (None, None), scale=0.02),
        "wi": ParamSpec((e, d, f), ("experts", "fsdp", None)),
        "wg": ParamSpec((e, d, f), ("experts", "fsdp", None)),
        "wo": ParamSpec((e, f, d), ("experts", None, "fsdp")),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        s["shared"] = {
            "wi": ParamSpec((d, fs), ("fsdp", "model")),
            "wg": ParamSpec((d, fs), ("fsdp", "model")),
            "wo": ParamSpec((fs, d), ("model", "fsdp")),
        }
    return s


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cfg.top_k, (c + 127) // 128 * 128)  # lane-aligned


def moe_ffn(p, x, cfg, *, constrain=None, dt=jnp.bfloat16):
    """x [B,T,d] -> (y [B,T,d], aux_loss scalar).

    constrain: optional fn(tensor, logical_axes) applying sharding
    constraints (injected by the distribution layer).
    """
    b, t, d = x.shape
    n_tok = b * t
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(n_tok, cfg)
    cst = constrain or (lambda v, axes: v)

    xf = x.reshape(n_tok, d)
    logits = (xf @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)                      # [T, k]
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * P_e
    f_e = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (n_tok * k)
    aux = e * jnp.sum(f_e * probs.mean(0)) * cfg.router_aux_weight

    # slot assignment: position of each (token, k) among its expert's tokens
    flat_e = top_e.reshape(-1)                                  # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # [T*k, E]
    pos = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)   # rank within expert
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, e * cap)         # overflow -> dump

    # scatter tokens into the expert buffer [E*C+1, d]
    tok_idx = jnp.repeat(jnp.arange(n_tok), k)
    buf = jnp.zeros((e * cap + 1, d), dt)
    buf = buf.at[dest].add(xf[tok_idx].astype(dt), mode="drop")
    xe = buf[: e * cap].reshape(e, cap, d)
    xe = cst(xe, ("experts", "capacity", None))

    # expert FFN (grouped matmul over the expert dim)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(dt))
    ye = cst(ye, ("experts", "capacity", None))

    # combine: gather back + probability-weighted sum over k
    yf = ye.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], yf[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    weighted = gathered * top_p.reshape(-1)[:, None].astype(dt)
    y = jnp.zeros((n_tok, d), dt).at[tok_idx].add(weighted)

    if "shared" in p:
        sh = p["shared"]
        hs = xf.astype(dt) @ sh["wi"].astype(dt)
        gs = xf.astype(dt) @ sh["wg"].astype(dt)
        y = y + (jax.nn.silu(gs) * hs) @ sh["wo"].astype(dt)
    return y.reshape(b, t, d), aux
