"""Multi-head Latent Attention (DeepSeek-V2) — train, prefill, decode.

The decode path uses the *absorbed* formulation: W_uk is folded into the
query and W_uv into the output so the cache holds only the compressed
latent c_kv [B,S,kv_lora] + the shared rope key [B,S,rope_dim]; per-step
FLOPs contract against the latent directly, never re-expanding K/V.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.scan_utils import scan as _scan
from repro.models.params import ParamSpec

NEG_INF = -1e30


def mla_spec(cfg):
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": ParamSpec((cfg.d_model, cfg.n_heads, qk), ("fsdp", "model", None)),
        "wdkv": ParamSpec((cfg.d_model, cfg.kv_lora_rank), ("fsdp", None)),
        "wkr": ParamSpec((cfg.d_model, cfg.qk_rope_dim), ("fsdp", None)),
        "kv_norm": ParamSpec((cfg.kv_lora_rank,), (None,), init="ones"),
        "wuk": ParamSpec(
            (cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim), (None, "model", None)
        ),
        "wuv": ParamSpec(
            (cfg.kv_lora_rank, cfg.n_heads, cfg.v_head_dim), (None, "model", None)
        ),
        "wo": ParamSpec((cfg.n_heads, cfg.v_head_dim, cfg.d_model),
                        ("model", None, "fsdp")),
    }


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # [B, S, kv_lora]
    k_rope: jnp.ndarray  # [B, S, rope_dim]


def _latents(p, x, cfg, positions, dt):
    c_kv = x @ p["wdkv"].astype(dt)
    c_kv = layers.rmsnorm({"scale": p["kv_norm"]}, c_kv, cfg.rms_eps)
    k_r = (x @ p["wkr"].astype(dt))[:, :, None, :]  # [B,S,1,rope]
    k_r = layers.apply_rope(k_r, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_r


def _queries(p, x, cfg, positions, dt):
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"].astype(dt))
    q_n = q[..., : cfg.qk_nope_dim]
    q_r = layers.apply_rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return q_n, q_r


def _causal_bias(tq: int, s: int, offset) -> jnp.ndarray:
    qpos = offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(s)[None, :]
    return jnp.where(kpos <= qpos, 0.0, NEG_INF).astype(jnp.float32)


def mla_attention(p, x, cfg, *, positions, dt=jnp.bfloat16, return_cache=False,
                  cache_len: int = 0, constrain=None):
    """Full-sequence causal MLA (train / prefill), q-block chunked."""
    b, t, _ = x.shape
    cst = constrain or (lambda v_, a: v_)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    c_kv, k_r = _latents(p, x, cfg, positions, dt)
    q_n, q_r = _queries(p, x, cfg, positions, dt)
    q_n = cst(q_n, ("batch", None, "heads", None))
    k_n = jnp.einsum("btl,lnh->btnh", c_kv, p["wuk"].astype(dt))
    v = jnp.einsum("btl,lnh->btnh", c_kv, p["wuv"].astype(dt))
    k_n = cst(k_n, ("batch", None, "heads", None))
    v = cst(v, ("batch", None, "heads", None))

    chunk = 512 if (t >= 4096 and t % 512 == 0) else 0

    def attend(qn_b, qr_b, offset):
        scores = jnp.einsum("btnh,bsnh->bnts", qn_b, k_n)
        scores = scores + jnp.einsum("btnh,bsh->bnts", qr_b, k_r)
        scores = scores.astype(jnp.float32) * scale
        scores = scores + _causal_bias(qn_b.shape[1], t, offset)[None, None]
        w = jax.nn.softmax(scores, axis=-1).astype(dt)
        return jnp.einsum("bnts,bsnh->btnh", w, v)

    if chunk and t > chunk:
        nblk = t // chunk
        attend_ckpt = jax.checkpoint(attend)  # don't stack softmax residuals

        def body(_, xs):
            qn_b, qr_b, i = xs
            return None, attend_ckpt(qn_b, qr_b, i * chunk)

        qn_s = jnp.moveaxis(q_n.reshape(b, nblk, chunk, *q_n.shape[2:]), 1, 0)
        qr_s = jnp.moveaxis(q_r.reshape(b, nblk, chunk, *q_r.shape[2:]), 1, 0)
        _, outs = _scan(body, None, (qn_s, qr_s, jnp.arange(nblk)),
                        unroll=getattr(cfg, 'unroll_scans', False))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, t, cfg.n_heads, cfg.v_head_dim)
    else:
        out = attend(q_n, q_r, 0)
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
    if return_cache:
        cl = cache_len or t
        cache = MLACache(
            c_kv=cst(c_kv[:, -cl:], ("batch", "kv_seq", None)),
            k_rope=cst(k_r[:, -cl:], ("batch", "kv_seq", None)))
        return y, cache
    return y


def mla_decode(p, x, cfg, cache: MLACache, *, pos, dt=jnp.bfloat16,
               constrain=None):
    """Absorbed single-token decode: contractions stay in latent space."""
    cst = constrain or (lambda v_, a: v_)
    s = cache.c_kv.shape[1]
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    posv = pos[None] if pos.ndim == 0 else pos

    c_new, kr_new = _latents(p, x, cfg, posv, dt)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, pos % s, axis=1)
    k_r = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, pos % s, axis=1)
    c_kv = cst(c_kv, ("batch", "kv_seq", None))
    k_r = cst(k_r, ("batch", "kv_seq", None))

    q_n, q_r = _queries(p, x, cfg, posv, dt)
    # absorb W_uk into the query: q_lat [B,1,H,lora]
    q_lat = jnp.einsum("btnh,lnh->btnl", q_n, p["wuk"].astype(dt))
    scores = jnp.einsum("btnl,bsl->bnts", q_lat, c_kv)
    scores = scores + jnp.einsum("btnh,bsh->bnts", q_r, k_r)
    scores = scores.astype(jnp.float32) * scale
    bias = jnp.where(jnp.arange(s) <= pos, 0.0, NEG_INF).astype(jnp.float32)
    scores = scores + bias[None, None, None]
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    # absorbed output: contract attention against the latent, then W_uv
    out_lat = jnp.einsum("bnts,bsl->btnl", w, c_kv)
    out = jnp.einsum("btnl,lnh->btnh", out_lat, p["wuv"].astype(dt))
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
    return y, MLACache(c_kv=c_kv, k_rope=k_r)
