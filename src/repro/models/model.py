"""Model assembly: embeddings, scan-over-layers stacks, output heads,
losses, and the three execution modes (full/train, prefill, decode).

Scan-over-layers keeps compiled HLO size depth-independent (one layer
body + a loop), which is what makes 94-layer × 512-device AOT compiles
tractable. Heterogeneous stacks are expressed as *multiple homogeneous
scans*: DeepSeek's leading dense layers, and the VLM's grouped
(1 cross + k self) structure.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import blocks, layers, mamba, mla
from repro.models import params as pm
from repro.models.scan_utils import scan as _scan
from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------
def model_spec(cfg):
    s: dict = {}
    if cfg.n_codebooks:
        s["embed"] = ParamSpec((cfg.n_codebooks, cfg.vocab, cfg.d_model),
                               (None, "model", "fsdp"), scale=0.02)
    else:
        s["embed"] = ParamSpec((cfg.vocab, cfg.d_model), ("model", "fsdp"),
                               scale=0.02)
    if cfg.n_cross_layers:
        n_self = cfg.n_layers
        s["self_blocks"] = pm.stack(
            blocks.strip_markers(blocks.block_spec(cfg, moe_layer=False)), n_self)
        s["cross_blocks"] = pm.stack(blocks.cross_block_spec(cfg), cfg.n_cross_layers)
    elif cfg.first_dense:
        dense = blocks.strip_markers(blocks.block_spec(cfg, moe_layer=False))
        moe_b = blocks.strip_markers(blocks.block_spec(cfg, moe_layer=True))
        s["dense_blocks"] = pm.stack(dense, cfg.first_dense)
        s["blocks"] = pm.stack(moe_b, cfg.n_layers - cfg.first_dense)
    else:
        s["blocks"] = pm.stack(
            blocks.strip_markers(blocks.block_spec(cfg)), cfg.n_layers)
    s["final_norm"] = layers.rmsnorm_spec(cfg.d_model)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks:
            s["head"] = ParamSpec((cfg.n_codebooks, cfg.d_model, cfg.vocab),
                                  (None, "fsdp", "model"), scale=0.02)
        else:
            s["head"] = ParamSpec((cfg.d_model, cfg.vocab), ("fsdp", "model"),
                                  scale=0.02)
    return s


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(p, cfg, tokens, dt):
    if cfg.n_codebooks:
        return _audio_embed(p, cfg, tokens, dt)
    h = jnp.take(p["embed"].astype(dt), tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    return h


def _audio_embed(p, cfg, tokens, dt):
    """tokens [B,T,K] -> [B,T,d]: per-codebook table lookup, summed."""
    tables = p["embed"].astype(dt)  # [K, V, d]
    h = 0.0
    for k in range(cfg.n_codebooks):
        h = h + jnp.take(tables[k], tokens[..., k], axis=0)
    return h


def logits_fn(p, cfg, h, dt):
    if cfg.n_codebooks:
        return jnp.einsum("btd,kdv->btkv", h, p["head"].astype(dt))
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", h, p["embed"].astype(dt))
    return h @ p["head"].astype(dt)


# ---------------------------------------------------------------------------
# forward (full / prefill)
# ---------------------------------------------------------------------------
class ModelOutputs(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray
    caches: Any = ()


def forward(p, cfg, tokens, *, vision_embeds=None, mode="full",
            constrain=None, remat_policy=None, return_hidden=False):
    """tokens [B,T] (or [B,T,K] audio). mode: full | prefill.

    return_hidden=True skips the output head and returns the final hidden
    states in `.logits` (used by the fused chunked CE loss)."""
    dt = jnp.dtype(cfg.compute_dtype)
    cst = constrain or (lambda v, axes: v)
    if cfg.n_codebooks:
        h = _audio_embed(p, cfg, tokens, dt)
        t = tokens.shape[1]
    else:
        h = embed_tokens(p, cfg, tokens, dt)
        t = tokens.shape[1]
    h = cst(h, ("batch", "act_seq", None))
    positions = jnp.arange(t, dtype=jnp.int32)

    def layer_fn(h, lp, moe_layer):
        h2, cache, aux = blocks.block(
            lp, h, cfg, mode=mode, positions=positions,
            moe_layer=moe_layer, constrain=constrain, dt=dt)
        h2 = cst(h2, ("batch", "act_seq", None))
        return h2, cache, aux

    if remat_policy is not None:
        layer_fn = jax.checkpoint(layer_fn, policy=remat_policy,
                                  static_argnums=(2,))

    aux_total = jnp.zeros((), jnp.float32)
    caches: dict = {}

    if cfg.n_cross_layers:
        g = cfg.group_self
        sp = jax.tree.map(
            lambda x: x.reshape(cfg.n_cross_layers, g, *x.shape[1:]),
            p["self_blocks"])
        cross_caches = []

        def group_fn(h, xs):
            cross_p, self_p = xs
            h = blocks.cross_block(cross_p, h, vision_embeds, cfg, dt=dt)
            h = cst(h, ("batch", "act_seq", None))

            def inner(h, lp):
                h2, cache, aux = layer_fn(h, lp, False)
                return h2, (cache, aux)

            h, (cache, aux) = _scan(inner, h, self_p, unroll=cfg.unroll_scans)
            return h, (cache, aux.sum())

        h, (self_cache, aux_g) = _scan(group_fn, h, (p["cross_blocks"], sp), unroll=cfg.unroll_scans)
        aux_total += aux_g.sum()
        caches["self"] = self_cache
        if mode == "prefill":
            # cross-attention K/V from the (fixed) vision embeddings
            caches["cross"] = _cross_kv(p["cross_blocks"], cfg, vision_embeds, dt)
    else:
        if cfg.first_dense:
            def dense_fn(h, lp):
                h2, cache, aux = layer_fn(h, lp, False)
                return h2, (cache, aux)

            h, (dcache, daux) = _scan(dense_fn, h, p["dense_blocks"], unroll=cfg.unroll_scans)
            aux_total += daux.sum()
            caches["dense"] = dcache

        def moe_fn(h, lp):
            h2, cache, aux = layer_fn(h, lp, cfg.is_moe)
            return h2, (cache, aux)

        h, (cache, aux_l) = _scan(moe_fn, h, p["blocks"], unroll=cfg.unroll_scans)
        aux_total += aux_l.sum()
        caches["blocks"] = cache

    h = layers.rmsnorm(p["final_norm"], h, cfg.rms_eps)
    if return_hidden:
        return ModelOutputs(logits=h, aux_loss=aux_total, caches=())
    logits = logits_fn(p, cfg, h, dt)
    logits = cst(logits, ("batch", None, "model") if not cfg.n_codebooks
                 else ("batch", None, None, "model"))
    return ModelOutputs(logits=logits, aux_loss=aux_total,
                        caches=caches if mode == "prefill" else ())


def _cross_kv(cross_p, cfg, enc, dt):
    """Precompute cross-attention K/V for all cross layers: [L,B,S,KV,hd]."""

    def one(lp):
        k = jnp.einsum("bsd,dnh->bsnh", enc, lp["attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dnh->bsnh", enc, lp["attn"]["wv"].astype(dt))
        return attn_mod.KVCache(k=k, v=v)

    return jax.vmap(one)(cross_p)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def xent_loss(logits, labels, z_weight: float = 1e-4):
    """Stable CE with z-loss. labels [B,T] (or [B,T,K]); -1 = masked."""
    ce, zl, n = _xent_sums(logits, labels)
    return (ce + z_weight * zl) / jnp.clip(n, 1)


def _xent_sums(logits, labels):
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(
        logits32, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return ((lse - gold) * mask).sum(), ((lse ** 2) * mask).sum(), mask.sum()


def chunked_xent_loss(p, cfg, h, labels, *, chunk: int = 512,
                      z_weight: float = 1e-4):
    """Head matmul + CE fused per sequence block: the [B,T,V] logits tensor
    is never materialised (neither fwd nor — via rematerialised blocks —
    bwd). This is what bounds vocab-dominated memory for 128k-256k vocabs."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, t = h.shape[:2]
    nb = t // chunk
    hb = jnp.moveaxis(h.reshape(b, nb, chunk, *h.shape[2:]), 1, 0)
    lb = jnp.moveaxis(labels.reshape(b, nb, chunk, *labels.shape[2:]), 1, 0)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def block_sums(hs, ls):
        return _xent_sums(logits_fn(p, cfg, hs, dt), ls)

    def body(carry, xs):
        ce, zl, n = block_sums(*xs)
        return (carry[0] + ce, carry[1] + zl, carry[2] + n), None

    (ce, zl, n), _ = _scan(body, (0.0, 0.0, 0.0), (hb, lb), unroll=cfg.unroll_scans)
    return (ce + z_weight * zl) / jnp.clip(n, 1)


def loss_fn(p, cfg, batch, *, constrain=None, remat_policy=None):
    tokens, labels = batch["tokens"], batch["labels"]
    t = tokens.shape[1]
    lc = cfg.loss_chunk
    chunk = lc if (t >= 2048 and lc and t % lc == 0) else 0
    out = forward(p, cfg, tokens, constrain=constrain,
                  vision_embeds=batch.get("vision_embeds"),
                  remat_policy=remat_policy,
                  return_hidden=bool(chunk))
    if chunk:
        ce = chunked_xent_loss(p, cfg, out.logits, labels, chunk=chunk)
    else:
        ce = xent_loss(out.logits, labels)
    return ce + out.aux_loss.astype(jnp.float32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_caches(cfg, batch: int, cache_len: int, dt=jnp.bfloat16):
    """Abstract-shaped zero caches for every layer stack."""

    def attn_cache(n):
        cl = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        if cfg.kv_quant:
            zq = jnp.zeros((n, batch, cl, cfg.n_kv_heads, cfg.head_dim), jnp.int8)
            zs = jnp.ones((n, batch, cl, cfg.n_kv_heads, 1), jnp.float32)
            return attn_mod.QuantKVCache(k=zq, v=zq, k_scale=zs, v_scale=zs)
        z = jnp.zeros((n, batch, cl, cfg.n_kv_heads, cfg.head_dim), dt)
        return attn_mod.KVCache(k=z, v=z)

    def mla_cache(n):
        return mla.MLACache(
            c_kv=jnp.zeros((n, batch, cache_len, cfg.kv_lora_rank), dt),
            k_rope=jnp.zeros((n, batch, cache_len, cfg.qk_rope_dim), dt))

    def ssm_cache(n):
        return mamba.MambaCache(
            conv=jnp.zeros((n, batch, cfg.d_conv - 1, cfg.d_inner), dt),
            ssm=jnp.zeros((n, batch, cfg.d_inner, cfg.ssm_state), jnp.float32))

    def block_cache(n, moe=False):
        if cfg.block == "mamba":
            return blocks.BlockCache(kv=(), ssm=ssm_cache(n))
        if cfg.block == "hymba":
            return blocks.BlockCache(kv=attn_cache(n), ssm=ssm_cache(n))
        if cfg.attn_impl == "mla":
            return blocks.BlockCache(kv=mla_cache(n), ssm=())
        return blocks.BlockCache(kv=attn_cache(n), ssm=())

    caches: dict = {}
    if cfg.n_cross_layers:
        caches["self"] = jax.tree.map(
            lambda x: x.reshape(cfg.n_cross_layers, cfg.group_self, *x.shape[1:]),
            block_cache(cfg.n_layers))
        z = jnp.zeros((cfg.n_cross_layers, batch, cfg.vision_seq,
                       cfg.n_kv_heads, cfg.head_dim), dt)
        caches["cross"] = attn_mod.KVCache(k=z, v=z)
    else:
        if cfg.first_dense:
            caches["dense"] = block_cache(cfg.first_dense)
        caches["blocks"] = block_cache(cfg.n_layers - cfg.first_dense)
    return caches


def cache_logical_axes(cfg):
    """Logical sharding axes for every leaf of init_caches' pytree.

    Decode KV caches shard their *sequence* dim on the model axis
    (split-KV / FlashDecoding layout); SSM states shard d_inner (TP).
    """

    def attn_axes():
        a = ("layers", "batch", "kv_seq", None, None)
        if cfg.kv_quant:
            return attn_mod.QuantKVCache(k=a, v=a, k_scale=a, v_scale=a)
        return attn_mod.KVCache(k=a, v=a)

    def mla_axes():
        return mla.MLACache(c_kv=("layers", "batch", "kv_seq", None),
                            k_rope=("layers", "batch", "kv_seq", None))

    def ssm_axes():
        return mamba.MambaCache(conv=("layers", "batch", None, "model"),
                                ssm=("layers", "batch", "model", None))

    def block_axes():
        if cfg.block == "mamba":
            return blocks.BlockCache(kv=(), ssm=ssm_axes())
        if cfg.block == "hymba":
            return blocks.BlockCache(kv=attn_axes(), ssm=ssm_axes())
        if cfg.attn_impl == "mla":
            return blocks.BlockCache(kv=mla_axes(), ssm=())
        return blocks.BlockCache(kv=attn_axes(), ssm=())

    axes: dict = {}
    if cfg.n_cross_layers:
        grouped = jax.tree.map(
            lambda a: (None, *a),
            block_axes(),
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x) and len(x) > 0)
        axes["self"] = grouped
        axes["cross"] = attn_mod.KVCache(
            k=(None, "batch", "kv_seq", None, None),
            v=(None, "batch", "kv_seq", None, None))
    else:
        if cfg.first_dense:
            axes["dense"] = block_axes()
        axes["blocks"] = block_axes()
    return axes


def decode_step(p, cfg, tokens, caches, pos, *, constrain=None):
    """One decode step. tokens [B,1] (or [B,1,K]); pos scalar int32.

    Returns (logits [B,1,V...], new_caches).
    """
    dt = jnp.dtype(cfg.compute_dtype)
    cst = constrain or (lambda v, axes: v)
    if cfg.n_codebooks:
        h = _audio_embed(p, cfg, tokens, dt)
    else:
        h = embed_tokens(p, cfg, tokens, dt)
    h = cst(h, ("batch", "act_seq", None))
    new_caches: dict = {}

    def layer_fn(h, lp, cache, moe_layer):
        h2, new_cache, _ = blocks.block(
            lp, h, cfg, mode="decode", cache=cache, pos=pos,
            moe_layer=moe_layer, constrain=constrain, dt=dt)
        return cst(h2, ("batch", "act_seq", None)), new_cache

    if cfg.n_cross_layers:
        g = cfg.group_self
        sp = jax.tree.map(
            lambda x: x.reshape(cfg.n_cross_layers, g, *x.shape[1:]),
            p["self_blocks"])

        def group_fn(h, xs):
            cross_p, self_p, self_c, cross_c = xs
            # decode-time cross attention reuses the prefilled cross K/V
            hn = layers.rmsnorm(cross_p["norm1"], h, cfg.rms_eps)
            q = jnp.einsum("btd,dnh->btnh", hn, cross_p["attn"]["wq"].astype(dt))
            n_rep = cfg.n_heads // cfg.n_kv_heads
            y = attn_mod._sdpa(q, cross_c.k, cross_c.v, None, n_rep)
            y = jnp.einsum("btnh,nhd->btd", y, cross_p["attn"]["wo"].astype(dt))
            h = h + y
            hn = layers.rmsnorm(cross_p["norm2"], h, cfg.rms_eps)
            h = h + layers.ffn(cross_p["ffn"], hn, cfg.ffn, compute_dtype=dt)

            def inner(h, xs2):
                lp, c = xs2
                return layer_fn(h, lp, c, False)

            h, new_c = _scan(inner, h, (self_p, self_c), unroll=cfg.unroll_scans)
            return h, new_c

        h, new_self = jax.lax.scan(
            group_fn, h, (p["cross_blocks"], sp, caches["self"], caches["cross"]))
        new_caches["self"] = new_self
        new_caches["cross"] = caches["cross"]
    else:
        if cfg.first_dense:
            def dense_fn(h, xs):
                lp, c = xs
                return layer_fn(h, lp, c, False)

            h, ndc = _scan(dense_fn, h, (p["dense_blocks"], caches["dense"]), unroll=cfg.unroll_scans)
            new_caches["dense"] = ndc

        def moe_fn(h, xs):
            lp, c = xs
            return layer_fn(h, lp, c, cfg.is_moe)

        h, nc = _scan(moe_fn, h, (p["blocks"], caches["blocks"]), unroll=cfg.unroll_scans)
        new_caches["blocks"] = nc

    h = layers.rmsnorm(p["final_norm"], h, cfg.rms_eps)
    logits = logits_fn(p, cfg, h, dt)
    return logits, new_caches
