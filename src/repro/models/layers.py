"""Shared neural layers: norms, RoPE, linear helpers, gated FFNs.

All parameters are declared as ParamSpec trees (models/params.py); apply
functions take the materialised (or abstract) value trees. Logical
sharding axes used here:

  fsdp    — weight dim sharded over the data(+pod) axes (ZeRO-3 style)
  model   — tensor-parallel dim (heads / ff / vocab / experts)
  batch   — activation batch dim over (pod, data)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int):
    return {"scale": ParamSpec((d,), (None,), init="ones")}


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., T, n, head_dim]; positions broadcastable to [..., T]."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------
def linear_spec(d_in: int, d_out: int, axes=("fsdp", "model"), bias=False, scale=None):
    s = {"w": ParamSpec((d_in, d_out), axes, scale=scale)}
    if bias:
        s["b"] = ParamSpec((d_out,), (axes[1],), init="zeros")
    return s


def linear(p, x, compute_dtype=jnp.bfloat16):
    y = x @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# Gated FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def ffn_spec(d: int, d_ff: int, kind: str = "swiglu"):
    s = {
        "wi": ParamSpec((d, d_ff), ("fsdp", "model")),
        "wo": ParamSpec((d_ff, d), ("model", "fsdp")),
    }
    if kind != "mlp":
        s["wg"] = ParamSpec((d, d_ff), ("fsdp", "model"))
    return s


def ffn(p, x, kind: str = "swiglu", compute_dtype=jnp.bfloat16):
    dt = compute_dtype
    h = x @ p["wi"].astype(dt)
    if kind == "mlp":  # plain 2-matrix GELU MLP (MusicGen / classic)
        return jax.nn.gelu(h) @ p["wo"].astype(dt)
    g = x @ p["wg"].astype(dt)
    act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
    return (act * h) @ p["wo"].astype(dt)
