"""scan-or-unroll helper.

`lax.scan` keeps compiled HLO size depth-independent, but XLA's
cost_analysis counts a while-loop body ONCE regardless of trip count, so
FLOP/byte/collective accounting from a scanned program under-reports by
the trip count. The dry-run therefore compiles *analysis variants* with
``cfg.unroll_scans=True`` — identical algorithm, scans unrolled as Python
loops — at 1 and 2 layers, and extrapolates linearly in depth
(homogeneous stacks make this exact). See EXPERIMENTS §Roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan(f, init, xs, *, unroll: bool = False):
    """Drop-in for jax.lax.scan(f, init, xs) with optional full unroll."""
    if not unroll:
        return jax.lax.scan(f, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    if n == 0:  # empty stack (e.g. 0 MoE layers in an analysis variant)
        xi = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), xs)
        _, y_shape = jax.eval_shape(f, init, xi)
        ys0 = jax.tree.map(
            lambda s: jnp.zeros((0, *s.shape), s.dtype), y_shape)
        return init, ys0
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    first_leaves = jax.tree.leaves(ys[0])
    if not first_leaves:
        return carry, ys[0]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
