"""Declarative parameter system.

Models are described as trees of ``ParamSpec`` leaves (shape + logical
sharding + init recipe). From one declaration we derive:

  - concrete initialised parameters (``init_params``),
  - abstract ShapeDtypeStructs for AOT lowering (``abstract_params``) —
    the dry-run never allocates a single weight,
  - PartitionSpec trees for pjit in/out shardings (``pspecs``),
  - stacked per-layer variants for scan-over-layers (``stack``).

Logical axis names are resolved to mesh axes by repro.dist.sharding rules.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple          # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; default fan-in scaled


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tmap(f, tree):
    return jax.tree.map(f, tree, is_leaf=is_spec)


def abstract_params(tree, dtype=jnp.float32):
    return _tmap(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def logical_axes(tree):
    return _tmap(lambda s: s.axes, tree)


def stack(tree, n: int):
    """Prepend a layer dimension (for scan-over-layers stacking)."""
    return _tmap(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), s.init, s.scale),
        tree,
    )


def init_params(tree, key, dtype=jnp.float32):
    """Materialise parameters; per-leaf keys are folded from the tree path."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)
    leaves = []
    for i, (path, spec) in enumerate(flat):
        k = jax.random.fold_in(key, i)
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale if spec.scale is not None else fan_in ** -0.5
            v = (jax.random.normal(k, spec.shape) * std).astype(dtype)
        leaves.append(v)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def count_params(tree) -> int:
    flat = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    total = 0
    for s in flat:
        n = 1
        for d in (s.shape if is_spec(s) else s.shape):
            n *= d
        total += n
    return total
