"""Batch analytics: corpus-scale root -> (doc, position) inverted indexing.

The sustained-throughput consumer of the whole stack — corpus chunks
stream through the stemmer megakernel into the postings reduction kernel
(kernels/postings.py) with no per-word host work, shard over the
``("data",)`` mesh, and checkpoint per chunk (DESIGN.md §8).
"""
from repro.index.builder import (IndexPartial, RootIndex, build_corpus_index,
                                 build_vocab, merge_partials)
from repro.index.reference import host_index, host_root_ids

__all__ = [
    "IndexPartial", "RootIndex", "build_corpus_index", "build_vocab",
    "merge_partials", "host_index", "host_root_ids",
]
