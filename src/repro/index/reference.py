"""Host numpy reference for the inverted-index build.

The trust chain mirrors every other parity suite in the repo: stemming
truth comes from ``core.stemmer.stem_batch`` (the reference the
megakernel is bit-identical to since PR 1), and the postings build is
plain vectorised numpy — ``bincount`` for the per-root counts and one
stable ``argsort`` for the CSR postings layout. The device build
(kernels/postings.py sort + segment-reduce + scatter) must reproduce
this bit for bit: same counts, same postings, same within-root order
(global word index).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import pyref
from repro.core import stemmer as core_stemmer


def host_root_ids(words: np.ndarray, arrays, vocab: np.ndarray, *,
                  chunk: int = 65536) -> np.ndarray:
    """words int32[W, 16] -> vocab ids int32[W] via the reference stemmer.

    Chunked so multi-million-word corpora don't materialise one giant
    intermediate; unmatched words get the drop id ``len(vocab)``.
    """
    n_roots = len(vocab)
    out = np.empty(words.shape[0], np.int32)
    for i in range(0, words.shape[0], chunk):
        w = jnp.asarray(words[i:i + chunk])
        root, source = core_stemmer.stem_batch(w, arrays)
        key = np.asarray(core_stemmer.pack_keys(root))
        source = np.asarray(source)
        at = np.searchsorted(vocab, key)
        found = vocab[np.minimum(at, n_roots - 1)] == key
        out[i:i + chunk] = np.where(found & (source != pyref.SRC_NONE),
                                    at, n_roots)
    return out


def host_index(ids: np.ndarray, doc_ids: np.ndarray, positions: np.ndarray,
               n_roots: int):
    """(ids, doc, pos) -> (counts int64[n_roots], docs, poss) CSR arrays.

    One stable argsort over the root ids keeps postings within a root in
    global word order — the layout :func:`repro.kernels.postings.
    finish_postings` produces on device.
    """
    valid = ids < n_roots
    order = np.argsort(ids[valid], kind="stable")
    counts = np.bincount(ids[valid], minlength=n_roots).astype(np.int64)
    return counts, doc_ids[valid][order].astype(np.int32), \
        positions[valid][order].astype(np.int32)
