"""The chunked corpus-index driver: stream -> megakernel -> postings ->
checkpointed partials -> one merged RootIndex.

Each corpus chunk is one ``ops.build_root_index`` call — stemmer
megakernel chained into the postings reduction kernel in a single jit
scope (sharded over the ``("data",)`` mesh when given one). The host
loop is over *chunks only*; per-word work never leaves the device, and
the per-chunk partials merge with vectorised searchsorted/scatter numpy
(no word loop there either).

Checkpointing: with ``checkpoint_dir`` every completed chunk lands as an
``.npz`` partial plus an atomically-rewritten ``manifest.json`` that
records the vocab fingerprint and, per chunk, the word range, the
``DictStore`` version pinned while stemming it, and the sha256 content
hash of the partial file. Partials are written tmp-then-rename and
verified by readback + hash before the rename, so a torn write (crash,
injected fault) never leaves a renamed-but-corrupt chunk; ``resume=True``
replays the manifest — completed chunks load from disk (their stream
items are consumed and cross-checked, not recomputed) *after* their
content hash is re-verified, and a missing / torn / hash-divergent
partial is transparently recomputed from its stream item instead of
poisoning the merge. Chunk compute and checkpoint writes both retry
(``chunk_retries``), so a build under an injected fault plan completes
bit-identical to a fault-free run (the chaos matrix in CI asserts it).
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass

import numpy as np

from repro.core import alphabet as ab
from repro.core import stemmer as core_stemmer

# schema 2: per-chunk "sha" content hashes (PR 9 checkpoint integrity)
MANIFEST_SCHEMA = 2


def build_vocab(arrays) -> np.ndarray:
    """RootDictArrays -> sorted unique packed 24-bit root keys int32[n].

    The union of the tri/quad/bi tables minus padding sentinels — every
    key the megakernel can emit as a match. Index root ids are positions
    in this array.
    """
    arrays, _, _ = core_stemmer.unwrap_dict(arrays)
    keys = np.unique(np.concatenate([np.asarray(t).ravel() for t in
                                     (arrays.tri, arrays.quad, arrays.bi)]))
    return keys[(keys >= 0) & (keys < (1 << 24))].astype(np.int32)


def vocab_fingerprint(vocab: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(vocab).tobytes()) \
        .hexdigest()[:16]


@dataclass(frozen=True)
class IndexPartial:
    """One chunk's device-built index slice (CSR over the chunk)."""

    counts: np.ndarray        # int64[n_roots]
    docs: np.ndarray          # int32[n_postings]
    positions: np.ndarray     # int32[n_postings]

    @property
    def n_postings(self) -> int:
        return int(self.docs.shape[0])


@dataclass(frozen=True)
class RootIndex:
    """The merged inverted index: root r's postings (sorted by global
    word order) sit at ``docs/positions[offsets[r] : offsets[r] +
    counts[r]]``; ``root_keys`` maps r back to its packed key."""

    root_keys: np.ndarray     # int32[n_roots] sorted packed keys
    counts: np.ndarray        # int64[n_roots]
    offsets: np.ndarray       # int64[n_roots] exclusive cumsum
    docs: np.ndarray          # int32[n_postings]
    positions: np.ndarray     # int32[n_postings]
    dict_versions: tuple = () # DictStore version pinned per chunk

    @property
    def n_roots(self) -> int:
        return int(self.root_keys.shape[0])

    @property
    def n_postings(self) -> int:
        return int(self.docs.shape[0])

    def postings_for(self, root) -> tuple[np.ndarray, np.ndarray]:
        """Packed key (or root string, e.g. "كتب") -> (docs, positions)."""
        key = (ab.pack_key(ab.encode_word(root)) if isinstance(root, str)
               else int(root))
        r = int(np.searchsorted(self.root_keys, key))
        if r >= self.n_roots or self.root_keys[r] != key:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32))
        lo, hi = int(self.offsets[r]), int(self.offsets[r] + self.counts[r])
        return self.docs[lo:hi], self.positions[lo:hi]


def merge_partials(partials, root_keys: np.ndarray,
                   dict_versions=()) -> RootIndex:
    """Concatenate per-chunk CSR partials into one RootIndex.

    Chunks cover consecutive word ranges, so within a root the merged
    postings are just each chunk's run back to back — computed with one
    searchsorted + scatter per chunk (vectorised over its postings).
    """
    n_roots = root_keys.shape[0]
    counts = np.zeros(n_roots, np.int64)
    for p in partials:
        counts += p.counts
    offsets = np.cumsum(counts) - counts
    total = int(counts.sum())
    docs = np.zeros(total, np.int32)
    positions = np.zeros(total, np.int32)
    base = np.zeros(n_roots, np.int64)
    for p in partials:
        ends = np.cumsum(p.counts)
        j = np.arange(p.n_postings, dtype=np.int64)
        rid = np.searchsorted(ends, j, side="right")
        dest = offsets[rid] + base[rid] + (j - (ends[rid] - p.counts[rid]))
        docs[dest] = p.docs
        positions[dest] = p.positions
        base += p.counts
    return RootIndex(root_keys=root_keys, counts=counts, offsets=offsets,
                     docs=docs, positions=positions,
                     dict_versions=tuple(dict_versions))


# ---------------------------------------------------------------------------
# checkpoint plumbing
# ---------------------------------------------------------------------------
def _chunk_path(ckpt_dir: str, i: int) -> str:
    return os.path.join(ckpt_dir, f"chunk_{i:06d}.npz")


def _file_sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()[:16]


def _write_manifest(ckpt_dir: str, manifest: dict) -> None:
    tmp = os.path.join(ckpt_dir, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(ckpt_dir, "manifest.json"))


def _load_manifest(ckpt_dir: str) -> dict | None:
    path = os.path.join(ckpt_dir, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _read_partial(path: str) -> IndexPartial:
    with np.load(path) as z:
        return IndexPartial(counts=z["counts"].astype(np.int64),
                            docs=z["docs"], positions=z["positions"])


def _load_partial(ckpt_dir: str, i: int,
                  want_sha: str | None = None) -> IndexPartial | None:
    """Load chunk i if its file exists, parses, and (when the manifest
    carries one) matches the recorded content hash; None otherwise — a
    torn or corrupt partial is a recompute, never an error."""
    path = _chunk_path(ckpt_dir, i)
    if not os.path.exists(path):
        return None
    if want_sha is not None and _file_sha(path) != want_sha:
        return None
    try:
        return _read_partial(path)
    except Exception:
        return None


def _write_partial(ckpt_dir: str, i: int, part: IndexPartial,
                   injector=None, retries: int = 2) -> str:
    """Write chunk i tmp-then-rename with readback verification; returns
    the renamed file's content hash. An injected (or real) torn write is
    caught by the readback and retried up to ``retries`` times."""
    path = _chunk_path(ckpt_dir, i)
    tmp = path + ".tmp"
    last = None
    for _ in range(retries + 1):
        with open(tmp, "wb") as f:
            np.savez(f, counts=part.counts, docs=part.docs,
                     positions=part.positions)
        if injector is not None:
            injector.on_checkpoint(tmp)     # may tear the file
        try:
            got = _read_partial(tmp)
            if (got.n_postings != part.n_postings
                    or not np.array_equal(got.counts, part.counts)):
                raise IOError("readback diverges from the in-memory partial")
        except Exception as e:
            last = e
            continue
        sha = _file_sha(tmp)
        os.replace(tmp, path)
        return sha
    raise IOError(f"chunk {i}: checkpoint write still corrupt after"
                  f" {retries + 1} attempts: {last}")


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------
def build_corpus_index(stream, roots, *, mesh=None, checkpoint_dir=None,
                       resume: bool = False, block_b: int = 2048,
                       block_w: int = 2048, interpret: bool | None = None,
                       injector=None, chunk_retries: int = 2,
                       **stem_kw) -> RootIndex:
    """Stream of ``core.corpus.CorpusChunk`` -> merged :class:`RootIndex`.

    ``roots`` is a RootDictArrays, a ResolvedRootDict handle, or a live
    ``serve.DictStore`` — with a store, each chunk pins
    ``store.acquire()`` for its stemming launch and records the pinned
    version in the checkpoint manifest (the index vocabulary itself is
    frozen at build start, so mid-build publishes change *stemming* but
    never the id space). ``mesh`` shards every chunk over its ``data``
    axis. ``checkpoint_dir`` + ``resume`` give chunk-granular restart
    with bit-identical results; resumed partials are hash-verified and
    transparently recomputed if missing or torn. ``injector`` threads a
    ``serve.faults.FaultInjector`` through the chunk compute (site
    ``dispatch``) and the checkpoint writes (site ``checkpoint``);
    ``chunk_retries`` bounds per-chunk retry on either kind of failure.
    """
    from repro.kernels import ops  # lazy: keep index importable sans jax

    store = roots if hasattr(roots, "acquire") else None
    pinned = store.acquire().handle if store else roots
    vocab = build_vocab(pinned)
    fp = vocab_fingerprint(vocab)

    done: list[IndexPartial] = []
    versions: list[int] = []
    manifest = None
    if checkpoint_dir:
        os.makedirs(checkpoint_dir, exist_ok=True)
        if resume:
            manifest = _load_manifest(checkpoint_dir)
        if manifest is not None:
            if manifest["schema"] != MANIFEST_SCHEMA:
                raise ValueError(
                    f"checkpoint schema {manifest['schema']} !="
                    f" {MANIFEST_SCHEMA}")
            if manifest["vocab"] != fp:
                raise ValueError(
                    "checkpoint was built against a different vocabulary"
                    f" ({manifest['vocab']} != {fp}) — refusing to resume")
        else:
            manifest = {"schema": MANIFEST_SCHEMA, "vocab": fp,
                        "n_roots": int(vocab.shape[0]), "chunks": []}
    n_ckpt = len(manifest["chunks"]) if manifest else 0

    for i, ch in enumerate(stream):
        if i < n_ckpt:
            rec = manifest["chunks"][i]
            if rec["start_word"] != ch.start_word or \
                    rec["n_words"] != ch.n_words:
                raise ValueError(
                    f"resumed stream diverges at chunk {i}: checkpoint"
                    f" covers words [{rec['start_word']},"
                    f" +{rec['n_words']}), stream yields"
                    f" [{ch.start_word}, +{ch.n_words})")
            part = _load_partial(checkpoint_dir, i, rec.get("sha"))
            if part is not None:
                done.append(part)
                versions.append(rec["dict_version"])
                continue
            # missing / torn / hash-divergent partial: fall through and
            # recompute this chunk from its stream item (chunk-level
            # retry keeps the rest of the checkpoint usable)
        last = None
        for _ in range(chunk_retries + 1):
            dv = store.acquire() if store else None
            handle = dv.handle if dv else roots
            try:
                if injector is not None:
                    injector.on_dispatch()
                counts, docs, poss, n_post = ops.build_root_index(
                    ch.words, handle, vocab, ch.doc_ids, ch.positions,
                    mesh=mesh, block_b=block_b, block_w=block_w,
                    interpret=interpret, **stem_kw)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                last = e
                continue
            break
        else:
            raise RuntimeError(
                f"chunk {i}: compute still failing after"
                f" {chunk_retries + 1} attempts") from last
        n_post = int(n_post)
        part = IndexPartial(counts=np.asarray(counts).astype(np.int64),
                            docs=np.asarray(docs[:n_post]),
                            positions=np.asarray(poss[:n_post]))
        done.append(part)
        versions.append(dv.version if dv else 0)
        if checkpoint_dir:
            sha = _write_partial(checkpoint_dir, i, part,
                                 injector=injector, retries=chunk_retries)
            rec = {"i": i, "start_word": int(ch.start_word),
                   "n_words": int(ch.n_words),
                   "n_postings": part.n_postings,
                   "dict_version": versions[-1], "sha": sha}
            if i < len(manifest["chunks"]):
                manifest["chunks"][i] = rec     # recomputed torn chunk
            else:
                manifest["chunks"].append(rec)
            _write_manifest(checkpoint_dir, manifest)
    return merge_partials(done, vocab, dict_versions=versions)
