"""Device-pipelined execution of the paper's five stemmer stages.

The paper's pipelined FPGA processor (Fig 15) overlaps the five stages on
one word stream: while stage 5 compares word t, stage 1 is already
checking word t+4, giving the 28873x pipelined speedup. On a JAX device
mesh the analogue is one *stage per device* along a mesh axis:
microbatches flow stage-to-stage via ``ppermute`` in a software-pipelined
(skewed) loop of ``m + S - 1`` ticks, so all S devices are busy once the
pipeline fills.

``pipeline_map`` is generic over any list of bundle -> bundle stage
functions (the bundle pytree structure must be invariant, mirroring the
FPGA's fixed inter-stage registers). ``stemmer_stage_fns`` provides the
canonical 5-stage split of the stemmer matching the paper's datapath:
candidates / tri-compare / quad-compare / bi-compare / priority-select.

On a single host this degrades gracefully: with forced host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=S) the same SPMD
program runs as a software pipeline — numerically identical to
``core.stemmer.stem_batch``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import stemmer
from repro.kernels import ref as kref

N_SLOTS = 30  # 5 groups x 6 candidates (stem_datapath layout)


def pipeline_map(stage_fns, bundle, mesh, axis: str = "stage"):
    """Run ``stage_fns[s]`` on device s of ``mesh[axis]``, streaming the
    leading (microbatch) dimension of ``bundle`` through the stages.

    bundle: pytree of arrays with identical leading dim m (microbatches).
    Each stage fn maps a one-microbatch bundle (leading dim dropped) to a
    bundle of the same structure. Returns the bundle after all stages,
    replicated across the mesh.
    """
    stage_fns = list(stage_fns)
    s_count = len(stage_fns)
    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    if sizes.get(axis) != s_count:
        raise ValueError(
            f"mesh axis {axis!r} has size {sizes.get(axis)}, need {s_count}")
    leaves = jax.tree.leaves(bundle)
    m = leaves[0].shape[0]

    def body(bundle):
        idx = jax.lax.axis_index(axis)
        state0 = jax.tree.map(lambda x: jnp.zeros_like(x[0]), bundle)
        outs0 = jax.tree.map(jnp.zeros_like, bundle)

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (clipped index; drained ticks
            # produce values that are never emitted)
            fresh = jax.tree.map(
                lambda x: x[jnp.clip(t, 0, m - 1)], bundle)
            state = jax.tree.map(
                lambda f, s: jnp.where(idx == 0, f, s), fresh, state)
            state = jax.lax.switch(idx, stage_fns, state)
            # the last stage emits microbatch t - (S-1) once the pipe fills
            t_out = t - (s_count - 1)
            emit = (idx == s_count - 1) & (t_out >= 0)
            j = jnp.clip(t_out, 0, m - 1)
            outs = jax.tree.map(
                lambda o, s: o.at[j].set(jnp.where(emit, s, o[j])),
                outs, state)
            # hand this stage's result to the next stage for tick t+1
            perm = [(i, (i + 1) % s_count) for i in range(s_count)]
            state = jax.tree.map(
                lambda x: jax.lax.ppermute(x, axis, perm), state)
            return state, outs

        _, outs = jax.lax.fori_loop(0, m + s_count - 1, tick, (state0, outs0))
        # results live on the last stage only; psum replicates them
        return jax.tree.map(
            lambda x: jax.lax.psum(
                jnp.where(idx == s_count - 1, x, jnp.zeros_like(x)), axis),
            outs)

    f = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                  check_rep=False)
    return f(bundle)


def _slot_mask(groups) -> np.ndarray:
    mask = np.zeros(32, bool)
    for g in groups:
        mask[g * 6 : (g + 1) * 6] = True
    return mask


def _streamed_match_sorted(keys, dict_keys, chunk_keys: int):
    """OR-accumulating chunked sorted match: the jnp analogue of the
    megakernel's streamed Compare path (stem_fused._fused_pipeline_kernel).

    The sorted dictionary is swept in ``chunk_keys``-sized sentinel-padded
    tiles (each tile stays sorted, so per-tile searchsorted is exact) while
    the candidate keys stay live — on a device this bounds the Compare
    stage's working set the same way the kernel's tile-visit sweep does.
    """
    from repro.kernels import stem_match as sm  # sentinel constant only

    r = dict_keys.shape[0]
    n_tiles = max(1, -(-r // chunk_keys))
    padded = jnp.pad(dict_keys, (0, n_tiles * chunk_keys - r),
                     constant_values=sm.DICT_SENTINEL)

    def tick(t, acc):
        tile = jax.lax.dynamic_slice(padded, (t * chunk_keys,), (chunk_keys,))
        return acc | stemmer.match_sorted(keys, tile)

    return jax.lax.fori_loop(0, n_tiles, tick,
                             jnp.zeros(keys.shape, bool))


def stemmer_stage_fns(roots: "stemmer.RootDictArrays", *,
                      residency: str = "auto", chunk_keys: int = 1 << 14):
    """The paper's 5-stage split over a bundle of
    {words[mb,16], keys[mb,32], valid[mb,32], root[mb,4], source[mb]}.

    Stage 1 runs the character datapath (stages 1-4 of the paper fused,
    as in the Pallas datapath kernel); stages 2-4 are the Compare stage
    split per dictionary (tri / quad / bi comparator banks — ``valid``
    doubles as the running hit mask, the FPGA's inter-stage flag
    register); stage 5 is the priority select.

    residency mirrors the megakernel policy (DESIGN.md §5.3): "resident"
    matches against the whole dictionary at once, "streamed" sweeps it in
    ``chunk_keys``-sized tiles with an OR-accumulating hit mask, "auto"
    (default) streams any dictionary larger than ``chunk_keys``.
    """
    if residency not in ("resident", "streamed", "auto"):
        raise ValueError(f"unknown residency: {residency!r}")
    tri_mask = jnp.asarray(_slot_mask((0, 2, 3)))   # tri, restored, deinf-quad
    quad_mask = jnp.asarray(_slot_mask((1,)))
    bi_mask = jnp.asarray(_slot_mask((4,)))

    def candidates(b):
        keys, valid = kref.stem_datapath_ref(b["words"])
        return {**b, "keys": keys, "valid": valid}

    def compare(dict_keys, mask):
        streamed = residency == "streamed" or (
            residency == "auto" and dict_keys.shape[0] > chunk_keys)

        def fn(b):
            if streamed:
                hit = _streamed_match_sorted(b["keys"], dict_keys, chunk_keys)
            else:
                hit = stemmer.match_sorted(b["keys"], dict_keys)
            valid = jnp.where(mask[None, :], b["valid"] * hit, b["valid"])
            return {**b, "valid": valid.astype(jnp.int32)}
        return fn

    def select(b):
        hits = b["valid"][:, :N_SLOTS] > 0
        first = jnp.argmax(hits, axis=1)
        found = hits.any(axis=1)
        chosen = jnp.take_along_axis(b["keys"], first[:, None], 1)[:, 0]
        root = jnp.where(
            found[:, None],
            jnp.stack([(chosen >> 18) & 63, (chosen >> 12) & 63,
                       (chosen >> 6) & 63, chosen & 63], axis=1), 0)
        tags = jnp.asarray(
            [t for t in kref.GROUP_TAGS for _ in range(6)], jnp.int32)
        source = jnp.where(found, tags[first], 0)
        return {**b, "root": root, "source": source}

    return [
        candidates,
        compare(roots.tri, tri_mask),
        compare(roots.quad, quad_mask),
        compare(roots.bi, bi_mask),
        select,
    ]
