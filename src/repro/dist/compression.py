"""Int8 gradient compression with error feedback.

Cross-host gradient all-reduce is the bandwidth bottleneck when the
stemmer-LM trains over slow interconnect; symmetric int8 quantisation
cuts the wire format 4x. The quantisation residual is carried forward
and added to the next step's gradient (error feedback), which keeps the
long-run average unbiased — the standard EF-SGD construction.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-30


def quantise_tensor(x: jnp.ndarray):
    """x float[...] -> (q int8[...] in [-127, 127], scale float scalar).

    Symmetric round-to-nearest: x ~= q * scale, |x - q*scale| <= scale/2.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, _EPS)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, errors):
    """One EF round over lists of tensors.

    grads, errors: same-structure lists. Returns (dequantised, new
    errors): each tensor is quantised *after* adding the carried error,
    and the new error is exactly what the wire format lost this round.
    """
    deqs, new_errors = [], []
    for g, e in zip(grads, errors):
        target = g + e
        q, scale = quantise_tensor(target)
        dq = q.astype(g.dtype) * scale
        deqs.append(dq)
        new_errors.append(target - dq)
    return deqs, new_errors
