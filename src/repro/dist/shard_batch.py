"""Data-sharded megakernel launches: one super-tile per tick, split
across a mesh axis.

The paper scales the pipelined processor by adding parallel hardware;
the serving analogue is a *data* axis: one ``[n_dev * block_b, 16]``
super-tile per launch, ``shard_map`` slicing it into per-device
``[block_b, 16]`` tiles that run :func:`kernels.stem_fused.
stem_fused_pallas` concurrently, with the packed dictionaries
replicated on every device. The StemmerWorkload dispatch path selects
this with ``data_devices=N`` (see serve/engine.py); standalone callers
get the same contract as ``ops.extract_roots_fused`` — bit-identical to
``core.stemmer.stem_batch``, ragged batches padded and sliced back.

The jitted body is keyed on the (hashable) Mesh plus the kernel's
static config, so serving replays one trace per (mesh, tile shape,
dictionary shape, residency) — a dictionary hot swap with matching
shapes never re-traces, exactly as on the single-device path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import stemmer as core_stemmer
from repro.kernels import stem_fused as sf


def device_downshift_ladder(n_dev: int) -> list[int]:
    """Data-device counts the degradation ladder reshards through:
    ``n_dev`` halving down to 1, descending.

    Any count d <= n_dev serves bit-identically — :func:`shard_batch`
    pads each launch to ``d * block_b`` and the per-word kernel output
    is independent of tile packing — so mid-stream resharding (a device
    lost from the mesh, sustained faults) only changes throughput,
    never results. Halving keeps the rung count logarithmic and every
    rung a divisor-friendly mesh shape.
    """
    if n_dev < 1:
        raise ValueError(f"n_dev must be >= 1, got {n_dev}")
    out, d = [], n_dev
    while d > 1:
        out.append(d)
        d //= 2
    out.append(1)
    return out


def mesh_axis_size(mesh, axis: str) -> int:
    """Size of ``axis`` in ``mesh`` (duck-typed via sharding.axis_sizes)."""
    from repro.dist import sharding

    sizes = sharding.axis_sizes(mesh)
    if axis not in sizes:
        raise ValueError(
            f"mesh has no axis {axis!r} (axes: {tuple(mesh.axis_names)})")
    return int(sizes[axis])


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "infix", "match", "block_b",
                     "residency", "dict_block_r", "num_buffers",
                     "skip_index", "visit_budget", "with_checksum",
                     "interpret"))
def _shard_call(words, roots, *, mesh, axis, infix, match, block_b,
                residency, dict_block_r, num_buffers, skip_index,
                visit_budget, with_checksum, interpret):
    n_dev = mesh_axis_size(mesh, axis)
    b = words.shape[0]
    pad = (-b) % (n_dev * block_b)
    wp = jnp.pad(words, ((0, pad), (0, 0)))

    def local(w, r):
        return sf.stem_fused_pallas(
            w, r, infix=infix, match=match, block_b=block_b,
            residency=residency, dict_block_r=dict_block_r,
            num_buffers=num_buffers, skip_index=skip_index,
            visit_budget=visit_budget, interpret=interpret)

    f = shard_map(local, mesh=mesh, in_specs=(P(axis), P()),
                  out_specs=(P(axis), P(axis)), check_rep=False)
    root, source = f(wp, roots)
    root, source = root[:b], source[:b]
    if with_checksum:
        # retire-side integrity row, traced into the SAME program as the
        # sharded launch (b must be a multiple of block_b — the serving
        # ring's bucketed tiles always are)
        from repro.kernels.ops import _checksum_rows  # lazy: no cycle

        return root, source, _checksum_rows(root, source, block_b)
    return root, source


def shard_batch(words, roots, mesh, *, axis: str = "data",
                infix: bool = True, match: str = "bsearch",
                block_b: int = 256, residency: str = "auto",
                dict_block_r: int = 8, num_buffers: int = 2,
                skip_index: bool = True, visit_budget: int | None = None,
                with_checksum: bool = False, interpret: bool = False):
    """words int32[B,16] -> (root int32[B,4], source int32[B]), B split
    over ``mesh[axis]``.

    Same contract as ``ops.extract_roots_fused`` — including megabatches:
    each device's shard runs the whole grid-over-queue batch axis over
    its ``B / n_dev`` slice (chunked against ``visit_budget`` on the
    streamed path), so one sharded launch retires
    ``n_dev x megabatch_tiles`` queue tiles. ``roots`` accepts plain
    RootDictArrays or a pre-resolved ``ResolvedRootDict`` handle (the
    serving path — its pinned residency wins and its prebuilt tile
    stream replicates to every device, so hot swaps with matching shapes
    replay the cached trace). B is padded up to a multiple of
    ``n_dev * block_b`` and sliced back, so ragged final super-tiles are
    valid.
    """
    arrays, residency, _ = core_stemmer.unwrap_dict(roots, residency)
    residency = sf.choose_residency(arrays, residency, infix=infix)
    # roots passes through unchanged so a handle keeps its tile stream
    return _shard_call(words, roots, mesh=mesh, axis=axis, infix=infix,
                       match=match, block_b=block_b, residency=residency,
                       dict_block_r=dict_block_r, num_buffers=num_buffers,
                       skip_index=skip_index, visit_budget=visit_budget,
                       with_checksum=with_checksum, interpret=interpret)
