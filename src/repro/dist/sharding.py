"""Logical-axis -> mesh-axis resolver for the ParamSpec system.

Model code names dimensions by *role* ("fsdp", "model", "batch", ...);
this module maps roles onto whatever mesh the launcher built. Rules:

  - each role has an ordered mesh-axis group; data-parallel roles
    ("batch", "fsdp") span ("data", "pod") so multi-pod meshes shard the
    full data-parallel group;
  - a dimension shards on the longest group prefix whose device product
    divides it (prefix backoff: a batch of 16 on data=16 x pod=2 falls
    back from the 32-way group to 16-way "data"); otherwise it
    replicates;
  - mesh axes are used at most once per parameter ("experts" taking
    "model" stops a later "model" dim from reusing it);
  - group members absent from the mesh are skipped, so the same specs
    resolve on single-pod and multi-pod meshes.

Meshes are duck-typed: only ``axis_names`` and ``devices.shape`` are
read, so tests can pass lightweight fakes.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import PartitionSpec as P

# role -> ordered candidate mesh axes
GROUPS = {
    "batch": ("data", "pod"),
    "fsdp": ("data", "pod"),
    "model": ("model",),
    "heads": ("model",),
    "experts": ("model",),
    "kv_seq": ("model",),
    "vocab": ("model",),
}
# never sharded: scan/stack dims and per-feature vectors
_REPLICATED = {"layers", "blocks", "cross_blocks", None}


def axis_sizes(mesh) -> dict:
    """Duck-typed mesh -> {axis name: device count}. The single place
    mesh introspection happens (resolve() and dist.shard_batch both go
    through it), reading only ``axis_names`` and ``devices.shape``."""
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def resolve(axes, shape, mesh) -> P:
    """(logical axes, dim sizes, mesh) -> PartitionSpec.

    Every returned entry divides its dimension exactly; anything that
    cannot shard cleanly replicates rather than erroring, so one spec
    tree serves every mesh geometry.
    """
    sizes = axis_sizes(mesh)
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        group = GROUPS.get(name, ())
        group = tuple(a for a in group if a in sizes and a not in used)
        entry = None
        for k in range(len(group), 0, -1):  # longest prefix first
            prefix = group[:k]
            prod = 1
            for a in prefix:
                prod *= sizes[a]
            if prod > 1 and dim % prod == 0:
                entry = prefix if k > 1 else prefix[0]
                used.update(prefix)
                break
        entries.append(entry)
    return P(*entries)
