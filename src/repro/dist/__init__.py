"""Distribution substrate: stage pipelining, data sharding, grad compression.

  pipeline     single-host/device-mesh microbatched stage pipeline — the
               paper's pipelined processor mapped onto a mesh axis
  shard_batch  data-sharded megakernel launches: one [n_dev * block_b, 16]
               super-tile split across a mesh axis per launch (the
               serving multi-device path; also exported as a function)
  sharding     logical-axis -> mesh-axis resolver for the ParamSpec system
  compression  int8 error-feedback gradient compression
"""
from repro.dist.shard_batch import mesh_axis_size, shard_batch

__all__ = ["mesh_axis_size", "shard_batch"]
