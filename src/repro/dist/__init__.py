"""Distribution substrate: stage pipelining, sharding rules, grad compression.

  pipeline     single-host/device-mesh microbatched stage pipeline — the
               paper's pipelined processor mapped onto a mesh axis
  sharding     logical-axis -> mesh-axis resolver for the ParamSpec system
  compression  int8 error-feedback gradient compression
"""
