"""repro: Parallel Hardware for Faster Morphological Analysis, as a multi-pod JAX framework (see README.md)."""
