"""Recovery benchmark: what a fault costs the serving path.

Per queue depth, the fault rows:

  recovery_baseline_q{qd}        fault-free drain (the denominator)
  recovery_dispatch_fault_q{qd}  one injected launch failure mid-drain;
                                 ``recovery_latency_us`` is the extra
                                 wall time the faulted drain paid over
                                 the baseline, ``identical`` asserts the
                                 recovered results are bit-identical
  recovery_retire_corrupt_q{qd}  one injected readback corruption caught
                                 by the retire checksum and redispatched
  recovery_shed_q{qd}            the same queue submitted against a
                                 queue_cap of half the depth with
                                 on_full="shed": ``shed_rate`` is the
                                 fraction rejected by admission control,
                                 ``served`` the requests that completed

plus the crash-safety rows (DESIGN.md §12):

  recovery_warm_restart_q{qd}    journaled engine killed mid-drain;
                                 ``warm_restart_s`` is Engine.recover +
                                 the replay drain, ``identical`` asserts
                                 (pre-crash + recovered) == fault-free
  recovery_journal_overhead_q{qd} the same drain with and without the
                                 write-ahead journal on the submit path,
                                 at >= 256 words/request (the journal's
                                 cost is per request, so the tax is
                                 quoted at a realistic request size);
                                 ``overhead_frac`` is the throughput tax
                                 (CI bounds it at 5%)
  recovery_rung_{label}_q{qd}    fault-free throughput at each rung of
                                 the degradation ladder (persistent,
                                 megabatch, per-tile, streamed-dict) —
                                 what a downshift costs

CI checks the recovery section exists in the smoke record, that every
faulted row recovered bit-identically, that the shed row actually shed
(admission control engaged, served + shed == submitted), that the warm
restart is bit-identical, the journal tax is within 5%, and that at
least three ladder rungs have positive throughput.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import corpus, stemmer
from repro.serve import (DictStore, Engine, FaultInjector, FaultPlan,
                         FaultSpec, Journal, StemmerWorkload, build_ladder)


def _drain(arrays, enc, qd, wpr, *, block_b, injector=None, engine_kw=None,
           **wl_kw):
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=block_b,
                                 max_inflight=2, injector=injector,
                                 **wl_kw), **(engine_kw or {}))
    t0 = time.perf_counter()
    rids = [eng.submit(enc[i * wpr:(i + 1) * wpr]) for i in range(qd)]
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    return eng, rids, dt


def _roots(eng, rids):
    return [None if eng.result(r).failure is not None
            else np.array(eng.result(r).roots) for r in rids]


def run(*, queue_depths=(8, 32), words_per_request=64, block_b=64,
        iters=3):
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    rows = []
    for qd in queue_depths:
        n_words = qd * words_per_request
        words, _, _ = corpus.build_corpus(n_words=n_words, seed=1)
        enc = corpus.encode_corpus(words)

        # warm the traces once so compile time never lands in a row
        _drain(arrays, enc, qd, words_per_request, block_b=block_b)

        base_dt = min(_drain(arrays, enc, qd, words_per_request,
                             block_b=block_b)[2] for _ in range(iters))
        eng, rids, _ = _drain(arrays, enc, qd, words_per_request,
                              block_b=block_b)
        baseline = _roots(eng, rids)
        rows.append(dict(name=f"recovery_baseline_q{qd}",
                         us_per_call=base_dt * 1e6, queue_depth=qd,
                         words_per_request=words_per_request,
                         wps=n_words / base_dt))

        for tag, spec in (("dispatch_fault", FaultSpec("dispatch", at=1)),
                          ("retire_corrupt", FaultSpec("retire", at=0))):
            best = None
            for _ in range(iters):
                inj = FaultInjector(FaultPlan(specs=(spec,)))
                eng, rids, dt = _drain(arrays, enc, qd, words_per_request,
                                       block_b=block_b, injector=inj)
                got = _roots(eng, rids)
                identical = all(
                    g is not None and np.array_equal(g, b)
                    for g, b in zip(got, baseline))
                rec = dict(dt=dt, identical=identical,
                           retries=eng.workload.retries_total,
                           checksum_failures=eng.workload.checksum_failures)
                if best is None or dt < best["dt"]:
                    best = rec
            rows.append(dict(
                name=f"recovery_{tag}_q{qd}",
                us_per_call=best["dt"] * 1e6, queue_depth=qd,
                recovery_latency_us=max(0.0, (best["dt"] - base_dt) * 1e6),
                retries=best["retries"],
                checksum_failures=best["checksum_failures"],
                identical=best["identical"]))

        cap = max(1, qd // 2)
        eng, rids, dt = _drain(arrays, enc, qd, words_per_request,
                               block_b=block_b,
                               engine_kw=dict(queue_cap=cap,
                                              on_full="shed"))
        served = sum(1 for r in rids if eng.result(r).failure is None)
        rows.append(dict(name=f"recovery_shed_q{qd}",
                         us_per_call=dt * 1e6, queue_depth=qd,
                         queue_cap=cap, shed=eng.shed, served=served,
                         shed_rate=eng.shed / qd))

        # -- warm restart: kill a journaled drain mid-stream, recover --
        with tempfile.TemporaryDirectory() as td:
            jp = os.path.join(td, "wal.jsonl")
            eng = Engine(StemmerWorkload(DictStore(arrays),
                                         block_b=block_b, max_inflight=2),
                         journal=Journal(jp))
            rids = [eng.submit(enc[i * words_per_request:
                                   (i + 1) * words_per_request])
                    for i in range(qd)]
            for _ in range(2):
                eng.step()                    # serve a little, then die
            done_before = {r: eng.result(r) for r in rids
                           if eng.result(r) is not None}
            t0 = time.perf_counter()
            eng2 = Engine.recover(jp, StemmerWorkload(DictStore(arrays),
                                                      block_b=block_b,
                                                      max_inflight=2))
            eng2.run_until_drained()
            warm = time.perf_counter() - t0
            merged = [done_before.get(r) or eng2.result(r) for r in rids]
            identical = all(
                m is not None and m.failure is None
                and b is not None and np.array_equal(m.roots, b)
                for m, b in zip(merged, baseline))
            rows.append(dict(name=f"recovery_warm_restart_q{qd}",
                             us_per_call=warm * 1e6, queue_depth=qd,
                             warm_restart_s=warm,
                             replayed=len(eng2.recovery.replayed),
                             identical=identical))

        # -- journal overhead: the WAL's tax on a clean drain ----------
        # best-of-3 on BOTH sides so one scheduler hiccup cannot fake a
        # tax; fsync batching left at the default (the row measures the
        # serving path an operator actually runs). The journal's cost
        # is per-REQUEST (one admit + one retire append), so the tax is
        # quoted at a production-representative request size — smoke
        # mode's 16-word toy requests would put a ~60us append next to
        # a ~400us serve and read as a fake double-digit tax.
        wpr_ovh = max(words_per_request, 256)
        words_ovh, _, _ = corpus.build_corpus(n_words=qd * wpr_ovh, seed=1)
        enc_ovh = corpus.encode_corpus(words_ovh)
        _drain(arrays, enc_ovh, qd, wpr_ovh, block_b=block_b)  # warm
        off_dt = min(_drain(arrays, enc_ovh, qd, wpr_ovh,
                            block_b=block_b)[2] for _ in range(3))
        on_dts = []
        for _ in range(3):
            with tempfile.TemporaryDirectory() as td:
                jr = Journal(os.path.join(td, "wal.jsonl"))
                on_dts.append(_drain(arrays, enc_ovh, qd, wpr_ovh,
                                     block_b=block_b,
                                     engine_kw=dict(journal=jr))[2])
                jr.close()
        on_dt = min(on_dts)
        rows.append(dict(name=f"recovery_journal_overhead_q{qd}",
                         us_per_call=on_dt * 1e6, queue_depth=qd,
                         words_per_request=wpr_ovh,
                         wps_journal_on=qd * wpr_ovh / on_dt,
                         wps_journal_off=qd * wpr_ovh / off_dt,
                         overhead_frac=max(0.0, on_dt / off_dt - 1.0)))

        # -- per-rung throughput: what each ladder downshift costs -----
        rungs = build_ladder(persistent=True, megabatch_tiles=2,
                             data_devices=1, resident_dict=True)
        for mode in rungs:
            wl_kw = dict(persistent=mode.persistent,
                         megabatch_tiles=mode.megabatch_tiles)
            eng, rids, best = None, None, None
            for _ in range(iters):
                eng = Engine(StemmerWorkload(DictStore(arrays),
                                             block_b=block_b,
                                             max_inflight=2, **wl_kw))
                eng.workload.residency_override = mode.residency
                t0 = time.perf_counter()
                rids = [eng.submit(enc[i * words_per_request:
                                       (i + 1) * words_per_request])
                        for i in range(qd)]
                eng.run_until_drained()
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            got = _roots(eng, rids)
            identical = all(g is not None and np.array_equal(g, b)
                            for g, b in zip(got, baseline))
            label = mode.label.replace(" ", "_")
            rows.append(dict(name=f"recovery_rung_{label}_q{qd}",
                             us_per_call=best * 1e6, queue_depth=qd,
                             rung=mode.label, wps=n_words / best,
                             identical=identical))
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        if "shed_rate" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"cap_{r['queue_cap']}_shed_{r['shed']}"
                  f"_served_{r['served']}")
        elif "recovery_latency_us" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"recovery_{r['recovery_latency_us']:.0f}us"
                  f"_retries_{r['retries']}"
                  f"_identical_{r['identical']}")
        elif "warm_restart_s" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"warm_{r['warm_restart_s'] * 1e3:.1f}ms"
                  f"_replayed_{r['replayed']}"
                  f"_identical_{r['identical']}")
        elif "overhead_frac" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"journal_tax_{r['overhead_frac'] * 100:.1f}pct"
                  f"_on_{r['wps_journal_on']:.0f}"
                  f"_off_{r['wps_journal_off']:.0f}Wps")
        elif "rung" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"{r['wps']:.1f}Wps_identical_{r['identical']}")
        else:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"{r['wps']:.1f}Wps_baseline")
    return rows


if __name__ == "__main__":
    main()
