"""Recovery benchmark: what a fault costs the serving path.

Per queue depth, four rows:

  recovery_baseline_q{qd}        fault-free drain (the denominator)
  recovery_dispatch_fault_q{qd}  one injected launch failure mid-drain;
                                 ``recovery_latency_us`` is the extra
                                 wall time the faulted drain paid over
                                 the baseline, ``identical`` asserts the
                                 recovered results are bit-identical
  recovery_retire_corrupt_q{qd}  one injected readback corruption caught
                                 by the retire checksum and redispatched
  recovery_shed_q{qd}            the same queue submitted against a
                                 queue_cap of half the depth with
                                 on_full="shed": ``shed_rate`` is the
                                 fraction rejected by admission control,
                                 ``served`` the requests that completed

CI checks the recovery section exists in the smoke record, that every
faulted row recovered bit-identically, and that the shed row actually
shed (admission control engaged, served + shed == submitted).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import corpus, stemmer
from repro.serve import (DictStore, Engine, FaultInjector, FaultPlan,
                         FaultSpec, StemmerWorkload)


def _drain(arrays, enc, qd, wpr, *, block_b, injector=None, engine_kw=None,
           **wl_kw):
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=block_b,
                                 max_inflight=2, injector=injector,
                                 **wl_kw), **(engine_kw or {}))
    t0 = time.perf_counter()
    rids = [eng.submit(enc[i * wpr:(i + 1) * wpr]) for i in range(qd)]
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    return eng, rids, dt


def _roots(eng, rids):
    return [None if eng.result(r).failure is not None
            else np.array(eng.result(r).roots) for r in rids]


def run(*, queue_depths=(8, 32), words_per_request=64, block_b=64,
        iters=3):
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    rows = []
    for qd in queue_depths:
        n_words = qd * words_per_request
        words, _, _ = corpus.build_corpus(n_words=n_words, seed=1)
        enc = corpus.encode_corpus(words)

        # warm the traces once so compile time never lands in a row
        _drain(arrays, enc, qd, words_per_request, block_b=block_b)

        base_dt = min(_drain(arrays, enc, qd, words_per_request,
                             block_b=block_b)[2] for _ in range(iters))
        eng, rids, _ = _drain(arrays, enc, qd, words_per_request,
                              block_b=block_b)
        baseline = _roots(eng, rids)
        rows.append(dict(name=f"recovery_baseline_q{qd}",
                         us_per_call=base_dt * 1e6, queue_depth=qd,
                         words_per_request=words_per_request,
                         wps=n_words / base_dt))

        for tag, spec in (("dispatch_fault", FaultSpec("dispatch", at=1)),
                          ("retire_corrupt", FaultSpec("retire", at=0))):
            best = None
            for _ in range(iters):
                inj = FaultInjector(FaultPlan(specs=(spec,)))
                eng, rids, dt = _drain(arrays, enc, qd, words_per_request,
                                       block_b=block_b, injector=inj)
                got = _roots(eng, rids)
                identical = all(
                    g is not None and np.array_equal(g, b)
                    for g, b in zip(got, baseline))
                rec = dict(dt=dt, identical=identical,
                           retries=eng.workload.retries_total,
                           checksum_failures=eng.workload.checksum_failures)
                if best is None or dt < best["dt"]:
                    best = rec
            rows.append(dict(
                name=f"recovery_{tag}_q{qd}",
                us_per_call=best["dt"] * 1e6, queue_depth=qd,
                recovery_latency_us=max(0.0, (best["dt"] - base_dt) * 1e6),
                retries=best["retries"],
                checksum_failures=best["checksum_failures"],
                identical=best["identical"]))

        cap = max(1, qd // 2)
        eng, rids, dt = _drain(arrays, enc, qd, words_per_request,
                               block_b=block_b,
                               engine_kw=dict(queue_cap=cap,
                                              on_full="shed"))
        served = sum(1 for r in rids if eng.result(r).failure is None)
        rows.append(dict(name=f"recovery_shed_q{qd}",
                         us_per_call=dt * 1e6, queue_depth=qd,
                         queue_cap=cap, shed=eng.shed, served=served,
                         shed_rate=eng.shed / qd))
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        if "shed_rate" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"cap_{r['queue_cap']}_shed_{r['shed']}"
                  f"_served_{r['served']}")
        elif "recovery_latency_us" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"recovery_{r['recovery_latency_us']:.0f}us"
                  f"_retries_{r['retries']}"
                  f"_identical_{r['identical']}")
        else:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"{r['wps']:.1f}Wps_baseline")
    return rows


if __name__ == "__main__":
    main()
