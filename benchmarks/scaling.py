"""Paper Fig 17 analogue: throughput vs number of input words.

The pipelined processor's advantage grows with word count as the 5-cycle
fill amortises; here the analogue is jit/dispatch amortisation + steady
microbatch streaming."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer


def run(sizes=(512, 2048, 8192, 32768), backend="sorted"):
    d = corpus.build_dictionary()
    da = stemmer.RootDictArrays.from_rootdict(d)
    rows = []
    for n in sizes:
        words, _, _ = corpus.build_corpus(n_words=n, seed=1)
        enc = jnp.asarray(corpus.encode_corpus(words))
        dt, _ = _bench(stemmer.stem_batch, enc, da, backend=backend, iters=2)
        rows.append({
            "name": f"scaling_n{n}",
            "backend": backend,
            "n_words": n,
            "us_per_call": 1e6 * dt,
            "wps": n / dt,
        })
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        print(f"{r['name']},{1e6 / r['wps']:.3f},{r['wps']:.1f}Wps")
    return rows


if __name__ == "__main__":
    main()
