"""Paper Fig 17 analogue: throughput vs number of input words.

The pipelined processor's advantage grows with word count as the 5-cycle
fill amortises; here the analogue is jit/dispatch amortisation + steady
microbatch streaming."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import corpus, stemmer


def run(sizes=(512, 2048, 8192, 32768), backend="sorted"):
    d = corpus.build_dictionary()
    da = stemmer.RootDictArrays.from_rootdict(d)
    rows = []
    for n in sizes:
        words, _, _ = corpus.build_corpus(n_words=n, seed=1)
        enc = jnp.asarray(corpus.encode_corpus(words))
        jax.block_until_ready(stemmer.stem_batch(enc, da, backend=backend))
        t0 = time.perf_counter()
        jax.block_until_ready(stemmer.stem_batch(enc, da, backend=backend))
        dt = time.perf_counter() - t0
        rows.append((n, n / dt))
    return rows


def main():
    for n, wps in run():
        print(f"scaling_n{n},{1e6 / wps:.3f},{wps:.1f}Wps")


if __name__ == "__main__":
    main()
