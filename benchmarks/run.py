"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines:
  throughput_*   Fig 16  (software vs non-pipelined vs pipelined Wps)
  scaling_*      Fig 17  (throughput vs word count)
  table6_*       Table 6 (accuracy ± infix processing)
  table7_*       Table 7 (per-root accuracy, top-frequency roots)
  compare_*      §6.4    (Compare-stage: linear vs sorted search)
  roofline_*     §Roofline (from dry-run records, if present)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import accuracy_bench, compare_stage, roofline, scaling, throughput

    sections = [
        ("throughput", throughput.main),
        ("scaling", scaling.main),
        ("accuracy", accuracy_bench.main),
        ("compare_stage", compare_stage.main),
        ("roofline", roofline.main),
    ]
    failed = 0
    for name, fn in sections:
        try:
            fn()
        except Exception:
            failed += 1
            print(f"{name}_FAILED,0,see_stderr", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
