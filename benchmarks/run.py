"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines:
  throughput_*   Fig 16  (software vs non-pipelined vs pipelined Wps,
                          plus multi-launch vs megakernel backends)
  scaling_*      Fig 17  (throughput vs word count)
  dict_scaling_* §5.3    (resident vs streamed megakernel over
                          dictionary sizes 2K -> 256K keys)
  dict_stream_pipeline_* §5.3 (pipelined streamed sweep: DMA ladder
                          depth x tile-visit skip index, visit counts
                          recorded per row)
  serve_throughput_*     (serve-path words/sec + p50/p95 request latency
                          through Engine + StemmerWorkload, queue depth x
                          block_b x megabatch depth)
  launch_overhead_*      (dispatch-overhead share: per-tile launches vs
                          one grid-over-queue megabatch vs the
                          persistent descriptor-ring kernel)
  table6_*       Table 6 (accuracy ± infix processing; CI floors the
                          root-recall rows since PR 7)
  table7_*       Table 7 (per-root accuracy, top-frequency roots)
  text_ingest_*  §7      (raw text in, roots out: front-end kernel +
                          fused text->roots chain + serve path, bytes/sec
                          and words/sec, clitic-stripping accuracy vs the
                          python reference)
  compare_*      §6.4    (Compare-stage: linear vs sorted search)
  corpus_index_* IR      (corpus-scale inverted-index build: words/sec +
                          index_build_s per corpus size through the
                          megakernel -> postings-reduction chain, host
                          numpy reference timings, device/host parity)
  recovery_*     robustness (fault-recovery cost on the serve path:
                          injected dispatch/retire faults vs fault-free
                          baseline, bit-identity flags, shed rate under
                          a queue cap)
  roofline_*     §Roofline (from dry-run records, if present)

Sections that return row dicts (throughput / scaling / compare_stage)
are also persisted machine-readable to ``BENCH_stemmer.json`` so the
perf trajectory is tracked across PRs (CI uploads it as an artifact).

Flags:
  --smoke        reduced sizes for CI (CPU, interpret-mode kernels)
  --json PATH    where to write the JSON record (default
                 ./BENCH_stemmer.json; "-" disables)
  --sections A,B run only the named sections (e.g. --sections
                 serve_throughput to iterate on the serve sweep alone);
                 untouched sections keep their rows in an existing JSON
                 record instead of being dropped — unless the existing
                 record's smoke flag differs (never mix smoke and
                 full-size rows in one record); unknown names error
  --list-sections print the known section names and exit
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import traceback
from pathlib import Path

SMOKE_PARAMS = {
    "throughput": dict(n_words=2048, seq_words=64),
    "scaling": dict(sizes=(512, 2048)),
    # 131072 keys > MAX_RESIDENT_KEYS: the smoke run always exercises one
    # streamed-dictionary configuration (CI fails if the section is absent)
    "dict_scaling": dict(sizes=(2048, 131072), n_words=512),
    # the pipelined sweep must keep skip-on AND skip-off rows at >= 128K
    # keys (CI asserts the skip index visits strictly fewer tiles) plus
    # the resident sanity row the 2x-regression guard compares against
    "dict_stream_pipeline": dict(sizes=(2048, 131072), n_words=256,
                                 num_bufferss=(1, 2), iters=1),
    # both overlap=off (inflight 1) and overlap=on rows must exist in the
    # smoke record (CI fails if either goes missing), plus the swap rows
    # and megabatch-on rows at every queue depth
    "serve_throughput": dict(queue_depths=(2, 4), block_bs=(32,),
                             words_per_request=16, iters=1,
                             inflight_depths=(1, 2), device_counts=(1,),
                             megabatch_tiless=(1, 2), swap_keys=4096),
    # CI asserts megabatch-on rows have strictly fewer dispatches per
    # word than per-tile at every depth, and a >= 4x drop at n_tiles 16
    "launch_overhead": dict(n_tiless=(1, 4, 16), block_b=32, iters=1),
    "accuracy": dict(n_words=2000),
    # bytes-in/roots-out rows + the clitic-accuracy row CI floors against
    # the committed baseline (grow_keys keeps a streamed fused row alive)
    "text_ingest": dict(n_docs=6, words_per_doc=24, iters=1,
                        grow_keys=131072, accuracy_words=400),
    "compare_stage": dict(n_keys=4096, dict_sizes=(512, 2048),
                          pallas_max_r=2048),
    # two corpus sizes so CI can check the words/sec + index_build_s pair
    # at each, plus the device-vs-host parity row
    "corpus_index": dict(sizes=(8192, 32768), chunk_words=8192,
                         block_b=1024, block_w=1024),
    # CI asserts every faulted row recovered bit-identically and that the
    # shed row's admission control engaged (served + shed == submitted)
    "recovery": dict(queue_depths=(8,), words_per_request=16, block_b=16,
                     iters=1),
}

# The authoritative section-name list, importable without jax (the heavy
# benchmark modules load lazily inside main): --sections validation and
# --list-sections both read it, and adding a section here without a
# matching entry in the table below fails loudly at startup.
SECTION_NAMES = (
    "throughput",
    "scaling",
    "dict_scaling",
    "dict_stream_pipeline",
    "serve_throughput",
    "launch_overhead",
    "accuracy",
    "text_ingest",
    "compare_stage",
    "corpus_index",
    "recovery",
    "roofline",
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI smoke runs")
    ap.add_argument("--json", default="BENCH_stemmer.json",
                    help='output path for the JSON record ("-" disables)')
    ap.add_argument("--sections", default="",
                    help="comma-separated section filter (default: all);"
                         " unfiltered sections keep their existing rows"
                         " in the JSON record")
    ap.add_argument("--list-sections", action="store_true",
                    help="print the known section names and exit")
    args = ap.parse_args(argv)

    if args.list_sections:
        for name in SECTION_NAMES:
            print(name)
        return

    only = {s for s in args.sections.split(",") if s}
    if only:
        unknown = only - set(SECTION_NAMES)
        if unknown:
            ap.error(f"unknown sections {sorted(unknown)}"
                     f" (choose from {sorted(SECTION_NAMES)})")

    from benchmarks import (accuracy_bench, compare_stage, corpus_index,
                            dict_scaling, launch_overhead, recovery,
                            roofline, scaling, serve_throughput,
                            text_ingest, throughput)

    fns = {
        "throughput": throughput.main,
        "scaling": scaling.main,
        "dict_scaling": dict_scaling.main,
        "dict_stream_pipeline": dict_scaling.main_pipeline,
        "serve_throughput": serve_throughput.main,
        "launch_overhead": launch_overhead.main,
        "accuracy": accuracy_bench.main,
        "text_ingest": text_ingest.main,
        "compare_stage": compare_stage.main,
        "corpus_index": corpus_index.main,
        "recovery": recovery.main,
        "roofline": roofline.main,
    }
    assert set(fns) == set(SECTION_NAMES), "SECTION_NAMES out of sync"
    sections = [(n, fns[n]) for n in SECTION_NAMES]
    if only:
        sections = [(n, f) for n, f in sections if n in only]
    record: dict = {"schema": 1, "smoke": args.smoke,
                    "platform": platform.platform(), "sections": {}}
    if only and args.json != "-" and Path(args.json).exists():
        # partial rerun: keep the other sections' rows — but only when
        # the old record was produced under the same smoke setting, so a
        # record never silently mixes smoke and full-size rows
        try:
            old = json.load(open(args.json))
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_json_merge_skipped,0,unreadable_existing:{e}")
        else:
            if old.get("smoke") == args.smoke:
                record["sections"] = dict(old.get("sections", {}))
            else:
                print("bench_json_merge_skipped,0,"
                      f"smoke_mismatch_old={old.get('smoke')}")
    try:
        import jax

        record["jax"] = jax.__version__
        record["backend"] = jax.default_backend()
    except Exception:
        pass

    failed = 0
    for name, fn in sections:
        kw = SMOKE_PARAMS.get(name, {}) if args.smoke else {}
        try:
            rows = fn(**kw)
        except Exception:
            failed += 1
            print(f"{name}_FAILED,0,see_stderr", flush=True)
            traceback.print_exc()
            continue
        if rows:
            record["sections"][name] = rows

    if args.json != "-":
        Path(args.json).write_text(json.dumps(record, indent=1))
        print(f"bench_json,0,{args.json}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
