"""Paper Fig 16 analogue: Wps throughput of the three execution models.

  software       — lax.scan word-at-a-time (the paper's Java baseline)
  non_pipelined  — batch-vectorised, all five stages barriered
  pipelined      — microbatched streaming (+ Pallas fused datapath)

plus the kernel-backend shootout the megakernel PR targets:

  kernel_multilaunch   — datapath kernel + 5 dict-match launches with
                         HBM round-trips between stages (the
                         pre-megakernel "fused" path)
  kernel_fused_*       — ONE pallas_call for stages 1-5, dictionaries
                         VMEM-resident, Compare = comparator bank or
                         in-kernel sorted search (stem_fused.py)

The paper reports 373.3 Wps (software), 2.08 MWps (non-pipelined, 5571x)
and 10.78 MWps (pipelined, 28873x). Absolute Wps here are CPU-host
numbers (kernel rows run interpret-mode on CPU); the *ratios* reproduce
the paper's ordering.
"""
from __future__ import annotations

import jax

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer
from repro.kernels import ops


def _row(name, backend, n, dt, sw_wps):
    wps = n / dt
    return {
        "name": name,
        "backend": backend,
        "us_per_call": 1e6 * dt,
        "wps": wps,
        "speedup_vs_software": wps / sw_wps,
    }


def run(n_words: int = 8192, seq_words: int = 512, backend: str = "sorted",
        kernel_rows: bool = True):
    words, _, _ = corpus.build_corpus(n_words=n_words, seed=0)
    enc = jax.numpy.asarray(corpus.encode_corpus(words))
    d = corpus.build_dictionary()
    da = stemmer.RootDictArrays.from_rootdict(d)

    rows = []
    # software baseline on a reduced word count (it's >1000x slower)
    t_sw, _ = _bench(stemmer.stem_sequential, enc[:seq_words], da,
                     backend=backend)
    sw_wps = seq_words / t_sw
    rows.append(_row("software", backend, seq_words, t_sw, sw_wps))

    t_np, _ = _bench(stemmer.stem_batch, enc, da, backend=backend)
    rows.append(_row("non_pipelined", backend, n_words, t_np, sw_wps))

    t_pl, _ = _bench(stemmer.stem_pipelined, enc, da, backend=backend,
                     microbatch=4096)
    rows.append(_row("pipelined", backend, n_words, t_pl, sw_wps))

    if kernel_rows:
        # the megakernel acceptance comparison: one launch vs six
        t_ml, _ = _bench(ops.extract_roots_multilaunch, enc, da,
                         interpret=True, iters=1)
        rows.append(_row("kernel_multilaunch", "pallas", n_words, t_ml, sw_wps))
        for match in ("bank", "bsearch"):
            t_f, _ = _bench(ops.extract_roots_fused, enc, da, match=match,
                            interpret=True, iters=2)
            rows.append(
                _row(f"kernel_fused_{match}", "fused", n_words, t_f, sw_wps))

    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        # CSV column 2 stays us-per-word (1e6/Wps) as in every section;
        # the JSON rows carry the whole-batch us_per_call separately.
        print(f"throughput_{r['name']},{1e6 / r['wps']:.3f},"
              f"{r['wps']:.1f}Wps_x{r['speedup_vs_software']:.1f}")
    by_name = {r["name"]: r for r in rows}
    if "kernel_multilaunch" in by_name and "kernel_fused_bsearch" in by_name:
        ratio = (by_name["kernel_fused_bsearch"]["wps"]
                 / by_name["kernel_multilaunch"]["wps"])
        print(f"throughput_fused_vs_multilaunch,{0:.3f},x{ratio:.2f}")
    return rows


if __name__ == "__main__":
    main()
