"""Paper Fig 16 analogue: Wps throughput of the three execution models.

  software       — lax.scan word-at-a-time (the paper's Java baseline)
  non_pipelined  — batch-vectorised, all five stages barriered
  pipelined      — microbatched streaming (+ Pallas fused datapath)

The paper reports 373.3 Wps (software), 2.08 MWps (non-pipelined, 5571x)
and 10.78 MWps (pipelined, 28873x). Absolute Wps here are CPU-host
numbers; the *ratios* reproduce the paper's ordering.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import corpus, stemmer


def _bench(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters, out


def run(n_words: int = 8192, seq_words: int = 512, backend: str = "sorted"):
    words, _, _ = corpus.build_corpus(n_words=n_words, seed=0)
    enc = jax.numpy.asarray(corpus.encode_corpus(words))
    d = corpus.build_dictionary()
    da = stemmer.RootDictArrays.from_rootdict(d)

    rows = []
    # software baseline on a reduced word count (it's >1000x slower)
    t_sw, _ = _bench(stemmer.stem_sequential, enc[:seq_words], da,
                     backend=backend)
    sw_wps = seq_words / t_sw
    rows.append(("software", sw_wps, 1.0))

    t_np, _ = _bench(stemmer.stem_batch, enc, da, backend=backend)
    np_wps = n_words / t_np
    rows.append(("non_pipelined", np_wps, np_wps / sw_wps))

    t_pl, _ = _bench(stemmer.stem_pipelined, enc, da, backend=backend,
                     microbatch=4096)
    pl_wps = n_words / t_pl
    rows.append(("pipelined", pl_wps, pl_wps / sw_wps))
    return rows


def main():
    for name, wps, speedup in run():
        print(f"throughput_{name},{1e6 / wps:.3f},{wps:.1f}Wps_x{speedup:.1f}")


if __name__ == "__main__":
    main()
