"""Paper §6.4 complexity discussion, realised: the Compare stage as
(a) linear comparator-bank scan (the paper's hardware, our Pallas kernel
path / dense backend) vs (b) the paper's proposed O(log R) tree search
(sorted binary search — both the jnp searchsorted form and the in-kernel
unrolled bisection the megakernel uses), across dictionary sizes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import bench as _bench
from repro.core import stemmer
from repro.kernels import ops


def match_unpacked(stems, roots):
    """Character-wise comparator bank — the paper's FPGA formulation
    before our 24-bit key packing: 4 int compares + AND-reduce per pair."""
    return (stems[:, None, :] == roots[None, :, :]).all(-1).any(-1)


def run(n_keys: int = 16384, dict_sizes=(512, 2048, 8192, 32768),
        pallas_max_r: int = 8192):
    """Returns rows: {"name", "backend", "dict_size", "us_per_call",
    "keys_per_s"}. Pallas rows run interpret-mode on CPU; the bank kernel
    is O(N*R) so it is capped at pallas_max_r to keep the sweep bounded."""
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 2**24, n_keys).astype(np.int32))
    stems = jnp.asarray(rng.integers(0, 64, (n_keys, 4)).astype(np.int32))
    rows = []
    for r in dict_sizes:
        dk = jnp.asarray(np.sort(rng.integers(0, 2**24, r)).astype(np.int32))
        droots = jnp.asarray(rng.integers(0, 64, (r, 4)).astype(np.int32))
        cases = [
            ("unpacked", lambda: jax.jit(match_unpacked)(stems, droots)),
            ("dense", lambda: jax.jit(stemmer.match_dense)(keys, dk)),
            ("sorted", lambda: jax.jit(stemmer.match_sorted)(keys, dk)),
            ("pallas_bsearch",
             lambda: ops.dict_match(keys, dk, strategy="bsearch",
                                    interpret=True)),
        ]
        if r <= pallas_max_r:
            cases.append(
                ("pallas_bank",
                 lambda: ops.dict_match(keys, dk, strategy="bank",
                                        interpret=True)))
        for name, call in cases:
            dt, _ = _bench(call, iters=2)
            rows.append({
                "name": f"{name}_R{r}",
                "backend": name,
                "dict_size": r,
                "us_per_call": 1e6 * dt,
                "keys_per_s": n_keys / dt,
            })
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        kps = r["keys_per_s"]
        print(f"compare_{r['name']},{1e6 / kps:.4f},{kps / 1e6:.2f}Mkeys_s")
    return rows


if __name__ == "__main__":
    main()
