"""Paper §6.4 complexity discussion, realised: the Compare stage as
(a) linear comparator-bank scan (the paper's hardware, our Pallas kernel
path / dense backend) vs (b) the paper's proposed O(log R) tree search
(sorted binary search), across dictionary sizes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stemmer


def match_unpacked(stems, roots):
    """Character-wise comparator bank — the paper's FPGA formulation
    before our 24-bit key packing: 4 int compares + AND-reduce per pair."""
    return (stems[:, None, :] == roots[None, :, :]).all(-1).any(-1)


def run(n_keys: int = 16384, dict_sizes=(512, 2048, 8192, 32768)):
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 2**24, n_keys).astype(np.int32))
    stems = jnp.asarray(rng.integers(0, 64, (n_keys, 4)).astype(np.int32))
    rows = []
    for r in dict_sizes:
        dk = jnp.asarray(np.sort(rng.integers(0, 2**24, r)).astype(np.int32))
        droots = jnp.asarray(rng.integers(0, 64, (r, 4)).astype(np.int32))
        cases = [
            ("unpacked", lambda: jax.jit(match_unpacked)(stems, droots)),
            ("dense", lambda: jax.jit(stemmer.match_dense)(keys, dk)),
            ("sorted", lambda: jax.jit(stemmer.match_sorted)(keys, dk)),
        ]
        for name, call in cases:
            jax.block_until_ready(call())
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            dt = time.perf_counter() - t0
            rows.append((name, r, n_keys / dt))
    return rows


def main():
    for name, r, kps in run():
        print(f"compare_{name}_R{r},{1e6 / kps:.4f},{kps/1e6:.2f}Mkeys_s")


if __name__ == "__main__":
    main()
