"""Shared warmup-then-time helper for all benchmark sections."""
from __future__ import annotations

import time

import jax


def bench(fn, *args, warmup=1, iters=3, **kw):
    """Mean seconds/call over ``iters`` timed calls after ``warmup``
    untimed ones (compile + cache fill). Returns (seconds, last_output)."""
    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters, out
