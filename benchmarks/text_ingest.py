"""Text ingestion: end-to-end bytes in, roots out (DESIGN.md §7).

What the pre-PR 7 benchmarks could not measure: every earlier section
feeds pre-packed word tiles, but real traffic is raw UTF-8 Arabic text.
This section streams synthesised documents (conjugated corpus words +
attached clitics + punctuation) through the text front end and records:

  frontend rows   the front-end launch alone (ops.text_to_words) and the
                  fused chain (ops.extract_roots_text, resident and
                  streamed dictionaries) — bytes/sec + words/sec
  serve row       the same documents through Engine +
                  TextAnalysisWorkload (dispatch/retire ring + megabatch)
  host row        the python-reference pipeline + stem_batch, the
                  software baseline
  accuracy row    clitic-stripping accuracy: fraction of tokens whose
                  kernel word row is bit-identical to the python
                  reference (CI floors this at the committed baseline),
                  plus the clitic recovery rate (stripped form == the
                  pre-clitic bare word) as an informational diagnostic

All numbers are interpret-mode CPU unless run on a TPU host.
"""
from __future__ import annotations

import numpy as np

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer
from repro.core import textnorm as tn
from repro.launch.serve import build_documents


def _tile(docs):
    chars, _, byte_off = tn.coalesce_docs(docs)
    t = max(128, -(-chars.shape[0] // 128) * 128)
    tile = np.zeros(t, np.int32)
    tile[:chars.shape[0]] = chars
    return tile


def main(n_docs: int = 48, words_per_doc: int = 128, iters: int = 2,
         n_tri: int = 1000, grow_keys: int = 131072, block_w: int = 128,
         accuracy_words: int = 4000):
    import jax.numpy as jnp

    from repro.kernels import ops

    d = corpus.build_dictionary(n_tri=n_tri, n_quad=120, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)

    docs = build_documents(n_docs, words_per_doc)
    n_bytes = sum(len(doc.encode("utf-8")) for doc in docs)
    tile = _tile(docs)
    n_words = int(np.asarray(tn.segment_geometry(tile).n_words))

    rows = []

    def row(name, variant, dt, extra=None):
        r = {"name": f"text_ingest_{name}", "variant": variant,
             "us_per_call": 1e6 * dt, "bytes_per_s": n_bytes / dt,
             "words_per_s": n_words / dt, "n_docs": n_docs,
             "n_bytes": n_bytes, "n_words": n_words}
        r.update(extra or {})
        rows.append(r)
        print(f"{r['name']},{r['us_per_call']:.0f},"
              f"bytes_per_s={r['bytes_per_s']:.0f}"
              f"_words_per_s={r['words_per_s']:.0f}")

    # -- front-end kernel alone (codepoints -> word tiles) ------------------
    dt, _ = _bench(ops.text_to_words, tile, block_w=block_w,
                   warmup=1, iters=iters)
    row("frontend", "frontend_only", dt)

    # -- fused chain: bytes -> roots, resident + streamed dictionaries -----
    dt, _ = _bench(ops.extract_roots_text, tile, arrays, block_w=block_w,
                   warmup=1, iters=iters)
    row("fused_resident", "fused", dt, {"residency": "resident"})
    if grow_keys:
        grown = corpus.grow_root_arrays(arrays, grow_keys, seed=3)
        dt, _ = _bench(ops.extract_roots_text, tile, grown,
                       block_w=block_w, residency="streamed",
                       warmup=1, iters=iters)
        row("fused_streamed", "fused", dt, {"residency": "streamed",
                                            "n_keys": grow_keys})

    # -- serve path: documents through the dispatch/retire ring ------------
    from repro.serve import DictStore, Engine, TextAnalysisWorkload

    def serve_once():
        store = DictStore(arrays)
        eng = Engine(TextAnalysisWorkload(store, block_b=block_w,
                                          megabatch_tiles=2))
        rids = [eng.submit(doc) for doc in docs]
        eng.run_until_drained(max_ticks=10_000)
        return sum(eng.result(r).n_words for r in rids)

    dt, served = _bench(serve_once, warmup=1, iters=iters)
    row("serve", "serve", dt, {"served_words": int(served)})

    # -- host baseline: python front end + stem_batch -----------------------
    def host_once():
        total = 0
        for doc in docs:
            w, _ = tn.analyze_text_py(doc)
            stemmer.stem_batch(jnp.asarray(w), arrays)
            total += w.shape[0]
        return total

    dt, _ = _bench(host_once, warmup=0, iters=1)
    row("host_reference", "host", dt)

    # -- clitic-stripping accuracy vs the python reference ------------------
    words, _, _ = corpus.build_corpus(n_words=accuracy_words, seed=11)
    pro = ("", "وال", "ب", "ف", "لل", "ك", "و")
    enc = ("", "ها", "هم", "كم", "ه", "نا", "هما")
    toks = [pro[i % len(pro)] + w + enc[i % len(enc)]
            for i, w in enumerate(words)]
    acc_doc = " ".join(toks)
    acc_tile = _tile([acc_doc])
    want, _ = tn.analyze_text_py(acc_doc)
    got_d, _, nw = ops.text_to_words(acc_tile, block_w=block_w)
    got = np.asarray(got_d)[:int(nw)]
    assert got.shape == want.shape, (got.shape, want.shape)
    match = (got == want).all(axis=1)
    bare = np.stack([tn.word_row_py(tuple(map(ord, w))) for w in words])
    recovered = (got == bare).all(axis=1)
    acc_row = {"name": "text_ingest_clitic_accuracy",
               "us_per_call": 0.0,
               "clitic_accuracy": float(match.mean()),
               "clitic_recovery": float(recovered.mean()),
               "n_words": int(match.size)}
    rows.append(acc_row)
    print(f"text_ingest_clitic_accuracy,0,"
          f"accuracy={acc_row['clitic_accuracy']:.4f}"
          f"_recovery={acc_row['clitic_recovery']:.4f}")
    return rows


if __name__ == "__main__":
    main()
