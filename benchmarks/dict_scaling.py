"""Dictionary-size scaling: resident vs streamed megakernel Compare.

The paper's dictionaries are tiny (its Compare banks hold the whole root
table on-chip); production lexicons run to hundreds of thousands of
entries. This section sweeps the packed dictionary size and times the
megakernel in both residency layouts (DESIGN.md §5.3):

  resident   dictionaries ride along as constant-index-map VMEM blocks
             (skipped past stem_fused.MAX_RESIDENT_KEYS — it would raise)
  streamed   (dict_block_r x 128) tiles over a minor grid axis with an
             OR-accumulating hit scratch — unbounded dictionary size

The recorded rows expose the resident/streamed crossover; the `sorted`
core-jnp backend rides along as the non-kernel reference. Dictionary
growth is synthetic (corpus.grow_root_arrays) but keeps the real root
keys, so real matches still occur at every size.

A second section, ``dict_stream_pipeline`` (:func:`run_pipeline`),
sweeps the explicitly pipelined streamed path: DMA ladder depth
(``num_buffers``) x tile-visit skip index on/off, recording the visit
counts next to the timings so the skip coverage is tracked per size.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer
from repro.kernels import ops
from repro.kernels import stem_fused as sf


def run(sizes=(2048, 8192, 32768, 131072, 262144), n_words: int = 2048,
        block_b: int = 256, dict_block_r: int = 8, match: str = "bsearch"):
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    base = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=n_words, seed=1)
    enc = jnp.asarray(corpus.encode_corpus(words))

    rows = []
    for n in sizes:
        da = corpus.grow_root_arrays(base, n, seed=n)
        total = sum(int(x.shape[0]) for x in (da.tri, da.quad, da.bi))

        dt, _ = _bench(stemmer.stem_batch, enc, da, backend="sorted",
                       warmup=1, iters=1)
        rows.append(_row(n, total, n_words, "jnp_sorted", dt))

        for residency in ("resident", "streamed"):
            if residency == "resident" and total > sf.MAX_RESIDENT_KEYS:
                continue  # over the VMEM budget: resident would raise
            dt, _ = _bench(ops.extract_roots_fused, enc, da, match=match,
                           block_b=block_b, residency=residency,
                           dict_block_r=dict_block_r, interpret=True,
                           warmup=1, iters=1)
            rows.append(_row(n, total, n_words, residency, dt,
                             dict_block_r=dict_block_r, match=match))
    return rows


def _row(n, total, n_words, variant, dt, *, section="dict_scaling",
         variant_key="residency", **extra):
    return {
        "name": f"{section}_n{n}_{variant}",
        "n_keys": total,
        "n_words": n_words,
        variant_key: variant,
        "us_per_call": 1e6 * dt,
        "wps": n_words / dt,
        **extra,
    }


def run_pipeline(sizes=(2048, 32768, 131072, 262144), n_words: int = 2048,
                 block_b: int = 128, dict_block_r: int = 8,
                 match: str = "bsearch", num_bufferss=(1, 2, 4),
                 iters: int = 2):
    """The explicitly pipelined streamed sweep: num_buffers (DMA ladder
    depth) x skip-index on/off over dictionary sizes, with the tile-visit
    counts recorded next to the timings.

    Every streamed row records ``visited_tiles`` (what the scalar-
    prefetched visit index actually sweeps, summed over batch tiles) and
    ``full_sweep_tiles`` (batch_tiles x dictionary tiles — what
    skip_index=False visits); at 128K+ keys CI asserts the skip index
    visits strictly fewer. A resident row rides along at sizes under the
    VMEM budget as the sanity reference the CI 2x-regression guard
    compares the best streamed row against (interpret-mode sanity on
    CPU, not a perf claim — the real ladder-depth sweep needs a TPU
    host, see ROADMAP).
    """
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    base = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=n_words, seed=1)
    enc = jnp.asarray(corpus.encode_corpus(words))

    rows = []
    for n in sizes:
        da = corpus.grow_root_arrays(base, n, seed=n)
        total = sum(int(x.shape[0]) for x in (da.tri, da.quad, da.bi))
        if total <= sf.MAX_RESIDENT_KEYS:
            dt, _ = _bench(ops.extract_roots_fused, enc, da, match=match,
                           block_b=block_b, residency="resident",
                           interpret=True, warmup=1, iters=iters)
            rows.append(_prow(n, total, n_words, "resident", dt,
                              block_b=block_b, match=match))
        for skip in (False, True):
            stats = sf.tile_visit_stats(enc, da, block_b=block_b,
                                        dict_block_r=dict_block_r,
                                        skip_index=skip)
            for nb in num_bufferss:
                dt, _ = _bench(ops.extract_roots_fused, enc, da,
                               match=match, block_b=block_b,
                               residency="streamed",
                               dict_block_r=dict_block_r, num_buffers=nb,
                               skip_index=skip, interpret=True,
                               warmup=1, iters=iters)
                variant = f"skip{'on' if skip else 'off'}_b{nb}"
                rows.append(_prow(
                    n, total, n_words, variant, dt, block_b=block_b,
                    match=match, dict_block_r=dict_block_r, num_buffers=nb,
                    skip_index=skip, visited_tiles=stats["visited"],
                    full_sweep_tiles=stats["full_sweep"],
                    batch_tiles=stats["batch_tiles"],
                    dict_tiles=stats["dict_tiles"]))
    return rows


def _prow(n, total, n_words, variant, dt, **extra):
    return _row(n, total, n_words, variant, dt,
                section="dict_stream_pipeline", variant_key="variant",
                **extra)


def main(**kw):
    rows = run(**kw)
    for r in rows:
        print(f"{r['name']},{1e6 / r['wps']:.3f},"
              f"{r['wps']:.1f}Wps_{r['n_keys']}keys")
    return rows


def main_pipeline(**kw):
    rows = run_pipeline(**kw)
    for r in rows:
        visits = (f"_{r['visited_tiles']}of{r['full_sweep_tiles']}tiles"
                  if "visited_tiles" in r else "")
        print(f"{r['name']},{r['us_per_call']:.3f},"
              f"{r['wps']:.1f}Wps_{r['n_keys']}keys{visits}")
    return rows


if __name__ == "__main__":
    main()
    main_pipeline()
