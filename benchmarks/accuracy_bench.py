"""Paper Tables 6 & 7 analogue: accuracy with/without infix processing,
plus per-root accuracy for the highest-frequency roots."""
from __future__ import annotations

from repro.core import accuracy


def main(n_words: int = 12000):
    res = accuracy.table6(n_words=n_words, seed=0)
    w, wo = res["with_infix"], res["without_infix"]
    print(f"table6_with_infix,{0:.3f},word_acc={w.accuracy:.3f}_root_recall={w.root_recall:.3f}")
    print(f"table6_without_infix,{0:.3f},word_acc={wo.accuracy:.3f}_root_recall={wo.root_recall:.3f}")
    for row in accuracy.table7(n_words=n_words, seed=0, top_k=10):
        print(f"table7_{row['root']},{0:.3f},"
              f"actual={row['actual']}_with={row['with_infix']}_without={row['without_infix']}")


if __name__ == "__main__":
    main()
