"""Paper Tables 6 & 7 analogue: accuracy with/without infix processing,
plus per-root accuracy for the highest-frequency roots.

Returns row dicts (CI-checked in BENCH_stemmer.json since PR 7): the
``table6_*`` rows carry word accuracy and root recall — the paper's
Table 6 measure, 87%/90.7% with/without-infix targets — so a speed PR
that silently degrades analysis quality fails the smoke record check
instead of landing.
"""
from __future__ import annotations

from repro.core import accuracy


def main(n_words: int = 12000):
    res = accuracy.table6(n_words=n_words, seed=0)
    rows = []
    for label, rep in (("with_infix", res["with_infix"]),
                       ("without_infix", res["without_infix"])):
        rows.append({"name": f"table6_{label}", "us_per_call": 0.0,
                     "infix": label == "with_infix",
                     "word_acc": float(rep.accuracy),
                     "root_recall": float(rep.root_recall),
                     "n_words": n_words})
        print(f"table6_{label},0,word_acc={rep.accuracy:.3f}"
              f"_root_recall={rep.root_recall:.3f}")
    for row in accuracy.table7(n_words=n_words, seed=0, top_k=10):
        rows.append({"name": f"table7_{row['root']}", "us_per_call": 0.0,
                     "root": row["root"], "actual": int(row["actual"]),
                     "with_infix": int(row["with_infix"]),
                     "without_infix": int(row["without_infix"])})
        print(f"table7_{row['root']},{0:.3f},"
              f"actual={row['actual']}_with={row['with_infix']}_without={row['without_infix']}")
    return rows


if __name__ == "__main__":
    main()
