"""§Roofline: assemble the per-(arch × shape) roofline table from the
dry-run JSON records (benchmarks/results/dryrun_*.json, single-pod)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"


def load_records(mesh: str = "16x16") -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob(f"dryrun_*_{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("roofline"):
            recs.append(r)
    return recs


def table(recs=None) -> str:
    recs = recs or load_records()
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'bound':>8s} {'useful%':>8s} {'HBM GB/dev':>10s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        rf = r["roofline"]
        mem_gb = r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {rf['compute_s']:10.3e} "
            f"{rf['memory_s']:10.3e} {rf['collective_s']:10.3e} "
            f"{rf['bottleneck']:>8s} {100 * r.get('useful_flops_frac', 0):8.1f} "
            f"{mem_gb:10.1f}")
    return "\n".join(lines)


def main():
    recs = load_records()
    for r in recs:
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        # roofline fraction: useful-compute time / dominant term
        frac = (r["model_flops"] / (r["chips"] * 197e12)) / dom if dom else 0
        print(f"roofline_{r['arch']}_{r['shape']},{dom * 1e6:.1f},"
              f"bound={rf['bottleneck']}_frac={frac:.3f}")


if __name__ == "__main__":
    print(table())
    main()
