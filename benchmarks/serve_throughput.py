"""Serve-path stemmer throughput: words/sec through Engine + StemmerWorkload.

The raw megakernel numbers (throughput/scaling sections) measure one
launch over a pre-formed batch; this section measures the full serving
path — queue admission, FIFO megabatch coalescing across requests,
megakernel launches through the dispatch/retire ring, per-request
scatter — over an (overlap x inflight depth x device count x megabatch
depth x queue depth x block_b) sweep. ``inflight=1`` is the synchronous
tick (overlap off); deeper rings overlap host coalescing/scatter with
device compute, and the off-vs-on gap at equal queue depth is the host
overhead the ring hides. ``megabatch_tiles>1`` rows coalesce that many
super-tiles per launch (the grid-over-queue path); every row also
records per-request p50/p95 submit-to-finish latency, so megabatch
coalescing can't silently trade tail latency for throughput.
``devices>1`` rows (when the backend has them) shard each super-tile
over a ("data",) mesh via dist.shard_batch.

The section also measures dictionary swap latency: a whole-lexicon
``publish()`` vs a sorted-merge ``publish_delta()`` of a few keys
against the same lexicon (rows ``serve_swap_full_*`` /
``serve_swap_delta_*``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer
from repro.kernels import ops
from repro.serve import DictStore, Engine, StemmerWorkload


def _serve_once(arrays, enc, *, bb, depth, n_dev, mb, qd, words_per_request,
                n_words):
    """One full serve of the queue; returns (DrainReport, per-request
    latency seconds).

    Latency is submit-to-finish per request, measured by stepping the
    engine manually (run_until_drained hides when each rid completes):
    every request is submitted up front — a fully loaded queue, so tail
    latency exposes what megabatch coalescing costs the first requests
    that wait for a deep tile to fill.
    """
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(
        store, block_b=bb, max_inflight=depth, data_devices=n_dev,
        megabatch_tiles=mb))
    t_submit = {}
    for i in range(qd):
        rid = eng.submit(enc[i * words_per_request:
                             (i + 1) * words_per_request])
        t_submit[rid] = time.perf_counter()
    latency = {}
    max_ticks = max(1000, 2 * n_words // bb + 2)
    ticks = 0
    while (eng.queue or eng.workload.active) and ticks < max_ticks:
        eng.step()
        ticks += 1
        now = time.perf_counter()
        for rid in t_submit:
            if rid not in latency and eng.result(rid) is not None:
                latency[rid] = now - t_submit[rid]
    assert len(latency) == qd, "serve did not drain"
    from repro.serve.engine import DrainReport

    return DrainReport(ticks=ticks, drained=True, pending=[]), \
        sorted(latency.values())


def _serve_rows(arrays, enc, *, queue_depths, block_bs, inflight_depths,
                device_counts, words_per_request, iters, megabatch_tiless):
    rows = []
    avail = len(jax.devices())
    for n_dev in device_counts:
        if n_dev > avail:
            print(f"serve_throughput_SKIP,0,devices_{n_dev}_gt_avail_{avail}")
            continue
        for bb in block_bs:
            # raw single-launch reference at this tile size (kernel
            # ceiling) — same config StemmerWorkload dispatches
            ref = jnp.asarray(enc[:bb])
            dt_raw, _ = _bench(ops.extract_roots_fused, ref, arrays,
                               block_b=bb, match="bsearch", dict_block_r=8,
                               warmup=1, iters=iters)
            for mb in megabatch_tiless:
                for depth in inflight_depths:
                    for qd in queue_depths:
                        n_words = qd * words_per_request
                        kw = dict(bb=bb, depth=depth, n_dev=n_dev, mb=mb,
                                  qd=qd, words_per_request=words_per_request,
                                  n_words=n_words)
                        # warmup: compile + jit-cache fill
                        rep, lat = _serve_once(arrays, enc, **kw)
                        t0 = time.perf_counter()
                        for _ in range(iters):
                            rep, lat = _serve_once(arrays, enc, **kw)
                        dt = (time.perf_counter() - t0) / iters
                        p50 = lat[len(lat) // 2]
                        p95 = lat[min(len(lat) - 1,
                                      int(0.95 * (len(lat) - 1) + 0.5))]
                        rows.append({
                            "name": (f"serve_throughput_q{qd}_b{bb}"
                                     f"_i{depth}_d{n_dev}_m{mb}"),
                            "queue_depth": qd,
                            "block_b": bb,
                            "inflight": depth,
                            "overlap": depth > 1,
                            "devices": n_dev,
                            "megabatch_tiles": mb,
                            "words_per_request": words_per_request,
                            "n_words": n_words,
                            "ticks": rep.ticks,
                            "us_per_call": 1e6 * dt,
                            "wps": n_words / dt,
                            "latency_p50_us": 1e6 * p50,
                            "latency_p95_us": 1e6 * p95,
                            "raw_kernel_wps": bb / dt_raw,
                        })
    return rows


def _swap_rows(arrays, *, swap_keys, iters):
    """Dictionary swap latency: whole-table publish vs sorted-merge delta.

    Both are measured against the same ``swap_keys``-key lexicon and both
    end in a resolved, publishable version; the delta inserts/removes a
    handful of keys, so its cost is one searchsorted merge + single-table
    upload rather than re-uploading every table. On a CPU backend both
    "uploads" are host memcpys, so the two rows land close together —
    the delta's win shows up where upload bandwidth is the cost (real
    accelerator interconnects); the rows exist to track that trajectory.
    """
    big = corpus.grow_root_arrays(arrays, swap_keys, seed=11)
    # a real whole-lexicon swap arrives as host data: re-upload all three
    # tables per publish (jnp.asarray of device-resident arrays would
    # no-op and undersell the full path's cost)
    host = {n: np.asarray(getattr(big, n)) for n in ("tri", "quad", "bi")}
    quad = np.asarray(big.quad)
    fresh = corpus._synthetic_keys(64, 4, seed=13, taken=set(quad.tolist()))
    old = quad[:32].tolist()
    # a delta drifts the store's current version, so time an alternating
    # forward/reverse pair — every publish applies cleanly
    fwd = {"insert": {"quad": fresh.tolist()}, "remove": {"quad": old}}
    rev = {"insert": {"quad": old}, "remove": {"quad": fresh.tolist()}}
    n_delta = len(fresh) + len(old)

    def publish_full(store):
        store.publish(stemmer.RootDictArrays(
            tri=jnp.asarray(host["tri"]), quad=jnp.asarray(host["quad"]),
            bi=jnp.asarray(host["bi"])))

    store = DictStore(big)
    rows = []
    for kind in ("full", "delta"):
        # warmup one publish of each kind (jit residency resolve etc.)
        if kind == "full":
            publish_full(store)
        else:
            store.publish_delta(**fwd)
        t0 = time.perf_counter()
        for i in range(2 * iters):
            if kind == "full":
                publish_full(store)
            else:
                store.publish_delta(**(rev if i % 2 == 0 else fwd))
        dt = (time.perf_counter() - t0) / (2 * iters)
        rows.append({
            "name": f"serve_swap_{kind}_{big.n_keys}",
            "swap": kind,
            "n_keys": int(big.n_keys),
            "delta_keys": n_delta if kind == "delta" else int(big.n_keys),
            "us_per_call": 1e6 * dt,
        })
    return rows


def run(queue_depths=(4, 16, 64), block_bs=(128, 256),
        words_per_request: int = 64, iters: int = 2,
        inflight_depths=(1, 2, 4), device_counts=(1,),
        megabatch_tiless=(1, 4), swap_keys: int = 32768):
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(
        n_words=max(queue_depths) * words_per_request, seed=1)
    enc = corpus.encode_corpus(words)

    rows = _serve_rows(arrays, enc, queue_depths=queue_depths,
                       block_bs=block_bs, inflight_depths=inflight_depths,
                       device_counts=device_counts,
                       words_per_request=words_per_request, iters=iters,
                       megabatch_tiless=megabatch_tiless)
    rows += _swap_rows(arrays, swap_keys=swap_keys, iters=iters)
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        if "wps" in r:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"{r['wps']:.1f}Wps_serve_vs_{r['raw_kernel_wps']:.1f}raw")
        else:
            print(f"{r['name']},{r['us_per_call']:.3f},"
                  f"swap_{r['swap']}_{r['n_keys']}keys")
    return rows


if __name__ == "__main__":
    main()
