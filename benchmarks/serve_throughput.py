"""Serve-path stemmer throughput: words/sec through Engine + StemmerWorkload.

The raw megakernel numbers (throughput/scaling sections) measure one
launch over a pre-formed batch; this section measures the full serving
path — queue admission, FIFO tile coalescing across requests, one
megakernel launch per tick, per-request scatter — over a (queue depth x
block_b) sweep. The gap between a row's serve Wps and the raw
single-launch Wps for the same tile size is the continuous-batching
overhead the Engine adds on top of the kernel.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer
from repro.kernels import ops
from repro.serve import DictStore, Engine, StemmerWorkload


def run(queue_depths=(4, 16, 64), block_bs=(128, 256),
        words_per_request: int = 64, iters: int = 2):
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(
        n_words=max(queue_depths) * words_per_request, seed=1)
    enc = corpus.encode_corpus(words)

    rows = []
    for bb in block_bs:
        # raw single-launch reference at this tile size (kernel ceiling) —
        # same block_b/match/dict_block_r config StemmerWorkload launches
        ref = jnp.asarray(enc[:bb])
        dt_raw, _ = _bench(ops.extract_roots_fused, ref, arrays,
                           block_b=bb, match="bsearch", dict_block_r=8,
                           warmup=1, iters=iters)
        for qd in queue_depths:
            n_words = qd * words_per_request

            def serve_once():
                store = DictStore(arrays)
                eng = Engine(StemmerWorkload(store, block_b=bb))
                for i in range(qd):
                    eng.submit(enc[i * words_per_request:
                                   (i + 1) * words_per_request])
                rep = eng.run_until_drained(
                    max_ticks=max(1000, 2 * n_words // bb + 2))
                assert rep.drained
                return rep

            rep = serve_once()  # warmup: compile + jit-cache fill
            t0 = time.perf_counter()
            for _ in range(iters):
                rep = serve_once()
            dt = (time.perf_counter() - t0) / iters
            rows.append({
                "name": f"serve_throughput_q{qd}_b{bb}",
                "queue_depth": qd,
                "block_b": bb,
                "words_per_request": words_per_request,
                "n_words": n_words,
                "ticks": rep.ticks,
                "us_per_call": 1e6 * dt,
                "wps": n_words / dt,
                "raw_kernel_wps": bb / dt_raw,
            })
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.3f},"
              f"{r['wps']:.1f}Wps_serve_vs_{r['raw_kernel_wps']:.1f}raw")
    return rows


if __name__ == "__main__":
    main()
