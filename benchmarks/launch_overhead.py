"""Dispatch-overhead share of serve latency: per-tile vs megabatch vs
persistent launches.

The paper's pipelined processor never stops between words; the serving
analogue of a pipeline stall is the per-``pallas_call`` dispatch cost.
This section times the same ``n_tiles x block_b`` words three ways:

  per_tile    n_tiles separate ``extract_roots_fused`` launches of one
              [block_b, 16] tile each — the pre-megabatch serving hot
              path, paying dispatch once per tile
  megabatch   ONE ``extract_roots_fused`` launch whose grid batch axis
              spans all n_tiles tiles (chunked only if the streamed
              visit table would blow the SMEM budget)
  persistent  ONE ``extract_roots_persistent`` launch fori_looping a
              device-side work-descriptor ring over the tiles

Each row records the ``pallas_call`` dispatch count (via
``ops.dispatch_count()``, which mirrors the kernel's chunk math) and
dispatches per word; the per_tile row additionally records
``dispatch_overhead_share`` — the fraction of its latency the best
coalesced mode at the same depth eliminates, i.e. the share of serve
latency that was dispatch, not compute. CI asserts megabatch rows beat
per-tile rows on dispatches per word and that the drop reaches 4x by
n_tiles >= 16.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import corpus, stemmer
from repro.kernels import ops

MODES = ("per_tile", "megabatch", "persistent")


def _time(fn, iters: int) -> float:
    jax.block_until_ready(fn())          # warmup: compile + jit-cache fill
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def run(n_tiless=(1, 4, 16, 64), block_b: int = 128, iters: int = 2,
        match: str = "bsearch"):
    d = corpus.build_dictionary(n_tri=1000, n_quad=120, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=max(n_tiless) * block_b, seed=1)
    enc = jnp.asarray(corpus.encode_corpus(words))

    rows = []
    for n_tiles in n_tiless:
        n_words = n_tiles * block_b
        batch = enc[:n_words]
        tiles = [enc[t * block_b:(t + 1) * block_b] for t in range(n_tiles)]

        def per_tile():
            out = [ops.extract_roots_fused(t, arrays, block_b=block_b,
                                           match=match) for t in tiles]
            return out[-1]

        def megabatch():
            return ops.extract_roots_fused(batch, arrays, block_b=block_b,
                                           match=match)

        def persistent():
            return ops.extract_roots_persistent(batch, arrays,
                                                block_b=block_b, match=match)

        by_mode = {}
        for mode, fn in (("per_tile", per_tile), ("megabatch", megabatch),
                         ("persistent", persistent)):
            dt = _time(fn, iters)
            ops.reset_dispatch_count()
            jax.block_until_ready(fn())
            dispatches = ops.dispatch_count()
            by_mode[mode] = (dt, dispatches)
            rows.append({
                "name": f"launch_overhead_{mode}_t{n_tiles}_b{block_b}",
                "mode": mode,
                "megabatch": mode != "per_tile",
                "n_tiles": n_tiles,
                "block_b": block_b,
                "n_words": n_words,
                "us_per_call": 1e6 * dt,
                "us_per_word": 1e6 * dt / n_words,
                "dispatches": dispatches,
                "dispatches_per_word": dispatches / n_words,
            })
        # dispatch-overhead share: what the best coalesced mode shaves
        # off the per-tile latency at this depth
        t_per, _ = by_mode["per_tile"]
        t_best = min(by_mode["megabatch"][0], by_mode["persistent"][0])
        rows[-3]["dispatch_overhead_share"] = max(0.0, 1.0 - t_best / t_per)
    return rows


def main(**kw):
    rows = run(**kw)
    for r in rows:
        share = r.get("dispatch_overhead_share")
        extra = f"_ovh{share:.2f}" if share is not None else ""
        print(f"{r['name']},{r['us_per_call']:.3f},"
              f"{r['dispatches']}disp_{r['us_per_word']:.2f}us_per_word"
              f"{extra}")
    return rows


if __name__ == "__main__":
    main()
