"""Corpus-scale inverted indexing: the batch analytics workload (§IR).

The Bessou & Touahria line of work (PAPERS.md) motivates the killer
batch scenario — root-based indexing for Arabic retrieval. This section
streams seeded synthetic corpora (core/corpus.py token-table streams)
through the stemmer-megakernel -> postings-reduction chain and records,
per corpus size:

  build rows   corpus_index_build_{n}: sustained words/sec and total
               index_build_s through repro.index.build_corpus_index
               (chunked driver, device-side postings build), plus the
               resulting posting count
  host rows    corpus_index_host_{n}: the vectorised numpy reference
               build (stem_batch ids + stable argsort) — the software
               baseline the device path is ratioed against in CI
  parity row   corpus_index_parity: bit-identity of the two indexes at
               the smallest size (counts, docs, positions) — a bench run
               can never record a fast-but-wrong build

All numbers are interpret-mode CPU unless run on a TPU host.
"""
from __future__ import annotations

import numpy as np

from benchmarks.timing import bench as _bench
from repro.core import corpus, stemmer


def main(sizes=(100_000, 1_000_000), chunk_words: int = 65536,
         words_per_doc: int = 500, n_tri: int = 2000, n_quad: int = 200,
         block_b: int = 2048, block_w: int = 2048, seed: int = 0):
    from repro import index as ix

    d = corpus.build_dictionary(n_tri=n_tri, n_quad=n_quad, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    vocab = ix.build_vocab(arrays)
    table = corpus.build_token_table()

    rows = []

    def row(name, dt, n_words, extra=None):
        r = {"name": f"corpus_index_{name}", "us_per_call": 1e6 * dt,
             "index_build_s": dt, "words_per_s": n_words / dt,
             "n_words": n_words, "n_roots": int(vocab.shape[0])}
        r.update(extra or {})
        rows.append(r)
        print(f"{r['name']},{r['us_per_call']:.0f},"
              f"words_per_s={r['words_per_s']:.0f}"
              f"_index_build_s={r['index_build_s']:.3f}")
        return r

    indexes = {}
    for n in sizes:
        def build(n=n):
            stream = corpus.stream_corpus_words(
                n, seed=seed, chunk_words=chunk_words,
                words_per_doc=words_per_doc, table=table)
            return ix.build_corpus_index(stream, arrays, block_b=block_b,
                                         block_w=block_w)
        dt, idx = _bench(build, warmup=0, iters=1)
        indexes[n] = idx
        row(f"build_{n}", dt, n, {"n_postings": idx.n_postings,
                                  "chunk_words": chunk_words,
                                  "block_w": block_w})

    # -- host numpy reference build (and the parity check input) -----------
    host = {}
    for n in sizes:
        def host_build(n=n):
            parts = []
            for ch in corpus.stream_corpus_words(
                    n, seed=seed, chunk_words=chunk_words,
                    words_per_doc=words_per_doc, table=table):
                ids = ix.host_root_ids(ch.words, arrays, vocab,
                                       chunk=chunk_words)
                parts.append((ids, ch.doc_ids.astype(np.int32),
                              ch.positions))
            ids = np.concatenate([p[0] for p in parts])
            docs = np.concatenate([p[1] for p in parts])
            poss = np.concatenate([p[2] for p in parts])
            return ix.host_index(ids, docs, poss, len(vocab))
        dt, ref = _bench(host_build, warmup=0, iters=1)
        host[n] = ref
        row(f"host_{n}", dt, n, {"n_postings": int(ref[0].sum())})

    # -- parity: the recorded numbers describe a bit-identical index --------
    n0 = min(sizes)
    idx, (w_counts, w_docs, w_poss) = indexes[n0], host[n0]
    identical = (np.array_equal(idx.counts, w_counts)
                 and np.array_equal(idx.docs, w_docs)
                 and np.array_equal(idx.positions, w_poss))
    assert identical, f"device index diverged from host reference at {n0}"
    rows.append({"name": "corpus_index_parity", "us_per_call": 0.0,
                 "identical": True, "n_words": n0,
                 "n_postings": idx.n_postings})
    print(f"corpus_index_parity,0,identical=True_n_words={n0}")
    return rows


if __name__ == "__main__":
    main()
