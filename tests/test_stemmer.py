"""Stemmer unit tests: paper worked examples + JAX-vs-pyref equivalence."""
import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import corpus, pyref, stemmer


@pytest.fixture(scope="module")
def dicts():
    d = corpus.build_dictionary(n_tri=800, n_quad=100, seed=7)
    return d, stemmer.RootDictArrays.from_rootdict(d)


def test_pack_unpack_keys_exhaustive_grid():
    """Batched JAX pack_keys/unpack_keys round-trip every valid 6-bit
    char code in every key position, plus the key-space corners, and
    agree with the scalar alphabet.pack_key reference. (A randomized
    hypothesis variant lives in test_properties.py; this grid keeps
    coverage on hosts without hypothesis.)"""
    import jax.numpy as jnp

    from repro.kernels import ops

    grid = np.zeros((4 * 64, 4), np.int32)
    for p in range(4):
        grid[p * 64:(p + 1) * 64, p] = np.arange(64)
    corners = np.array([[0, 0, 0, 0], [63, 63, 63, 63], [63, 0, 63, 0],
                        [0, 63, 0, 63], [1, 2, 3, 4]], np.int32)
    codes = np.concatenate([grid, corners])
    keys = np.asarray(stemmer.pack_keys(jnp.asarray(codes)))
    assert ((keys >= 0) & (keys < 2**24)).all()
    assert len(np.unique(keys[:4 * 64])) == 4 * 64 - 3  # all-zero row x4
    np.testing.assert_array_equal(
        np.asarray(ops.unpack_keys(jnp.asarray(keys))), codes)
    for row, key in zip(codes.tolist(), keys.tolist()):
        assert ab.pack_key(row) == key


# ---------------------------------------------------------------------------
# Paper worked examples (§3.1, §6.1)
# ---------------------------------------------------------------------------
def test_paper_example_afastasqaynakumuha(dicts):
    d, _ = dicts
    root, src = pyref.stem_word("أفاستسقيناكموها", d)
    assert root == "سقي"
    assert src == pyref.SRC_TRI


def test_paper_example_sayalaboon(dicts):
    d, _ = dicts
    root, src = pyref.stem_word("سيلعبون", d)
    assert root == "لعب"
    assert src == pyref.SRC_TRI


def test_paper_example_quadrilateral(dicts):
    d, _ = dicts
    # Fig 14: quadrilateral extraction with فت proclitics + ت suffix.
    root, src = pyref.stem_word("فتزحزحت", d)
    assert root == "زحزح"
    assert src == pyref.SRC_QUAD


def test_prefix_mask_stops_after_yeh():
    # سيلعبون: the ل after سي is a prefix letter but the person marker ي
    # terminates the run (paper Table 3 masks it). p options: -1, 0, 1 only.
    word = [int(c) for c in ab.encode_word("سيلعبون") if c]
    pp, ps = pyref.check_and_produce(word)
    assert pp == [True, True, False, False, False]
    tri, quad = pyref.generate_stems(word)
    enc = lambda w: tuple(int(c) for c in ab.encode_word(w) if c)
    assert enc("لعب") in tri
    assert enc("يلعب") in quad and enc("لعبو") in quad
    assert enc("عبو") not in tri  # p=2 masked


def test_suffix_mask_interrupted_run():
    # يكتبون: the ب breaks the suffix run; only و ن survive (paper §4.1).
    word = [int(c) for c in ab.encode_word("يكتبون") if c]
    _, ps = pyref.check_and_produce(word)
    assert ps == [False, False, False, False, True, True]


def test_infix_restore_hollow(dicts):
    d, _ = dicts
    root, src = pyref.stem_word("قال", d)
    assert root == "قول"
    assert src == pyref.SRC_RESTORED
    root, src = pyref.stem_word("قال", d, infix=False)
    assert src == pyref.SRC_NONE


def test_infix_remove_form3(dicts):
    d, _ = dicts
    root, src = pyref.stem_word("كاتب", d)
    assert root == "كتب"
    assert src == pyref.SRC_DEINFIX_TRI


def test_infix_remove_bilateral():
    d = pyref.RootDict.from_words(bi=["مد"])
    root, src = pyref.stem_word("ماد", d)
    assert root == "مد"
    assert src == pyref.SRC_DEINFIX_BI


def test_word_equal_to_root(dicts):
    d, _ = dicts
    assert pyref.stem_word("درس", d) == ("درس", pyref.SRC_TRI)
    assert pyref.stem_word("دحرج", d) == ("دحرج", pyref.SRC_QUAD)


# ---------------------------------------------------------------------------
# JAX implementation == pure-Python oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["dense", "sorted"])
@pytest.mark.parametrize("infix", [True, False])
def test_jax_matches_pyref_on_corpus(dicts, backend, infix):
    d, da = dicts
    words, _, _ = corpus.build_corpus(n_words=500, seed=3)
    enc = corpus.encode_corpus(words)
    roots_jax, src_jax = stemmer.stem_batch(enc, da, infix=infix, backend=backend)
    roots_jax, src_jax = np.asarray(roots_jax), np.asarray(src_jax)
    for i, w in enumerate(words):
        ref_root, ref_src = pyref.extract_root(enc[i], d, infix=infix)
        got = tuple(int(c) for c in roots_jax[i] if c)
        assert got == ref_root, (w, got, ref_root)
        assert int(src_jax[i]) == ref_src, (w, int(src_jax[i]), ref_src)


def test_sequential_equals_batch(dicts):
    _, da = dicts
    words, _, _ = corpus.build_corpus(n_words=64, seed=5)
    enc = corpus.encode_corpus(words)
    r1, s1 = stemmer.stem_batch(enc, da)
    r2, s2 = stemmer.stem_sequential(enc, da)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_pipelined_equals_batch(dicts):
    _, da = dicts
    words, _, _ = corpus.build_corpus(n_words=300, seed=6)
    enc = corpus.encode_corpus(words)
    r1, s1 = stemmer.stem_batch(enc, da)
    r2, s2 = stemmer.stem_pipelined(enc, da, microbatch=128)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_sorted_equals_dense_backend(dicts):
    _, da = dicts
    words, _, _ = corpus.build_corpus(n_words=400, seed=9)
    enc = corpus.encode_corpus(words)
    r1, s1 = stemmer.stem_batch(enc, da, backend="dense")
    r2, s2 = stemmer.stem_batch(enc, da, backend="sorted")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# ---------------------------------------------------------------------------
# Encoding
# ---------------------------------------------------------------------------
def test_encode_decode_roundtrip():
    for w in ["درس", "أفاستسقيناكموها", "سيلعبون", "قال"]:
        enc = ab.encode_word(w)
        assert ab.decode_word(enc) == ab.normalise(w)


def test_normalise_strips_diacritics():
    assert ab.normalise("دَرَسَ") == "درس"
    assert ab.normalise("أَدْرِسُ") == "ادرس"


def test_pack_unpack_key():
    for codes in [[1, 2, 3], [5, 6, 7, 8], [33, 1]]:
        k = ab.pack_key(codes)
        assert 0 <= k < 2**24
        padded = list(codes) + [0] * (4 - len(codes))
        assert ab.unpack_key(k) == padded


# ---------------------------------------------------------------------------
# Extended rule pool (beyond-paper; paper §7 future work)
# ---------------------------------------------------------------------------
def test_extended_defective_final(dicts):
    d, da = dicts
    # سقى (defective past of سقي) unrecoverable with paper rules...
    root, src = pyref.stem_word("سقى", d)
    assert src == pyref.SRC_NONE
    # ...recovered with the extended pool
    root, src = pyref.stem_word("سقى", d, extended=True)
    assert root == "سقي" and src == pyref.SRC_EXT_DEFECTIVE


def test_extended_hollow_yeh(dicts):
    d, da = dicts
    root, src = pyref.stem_word("باع", d, extended=True)
    assert root == "بيع" and src == pyref.SRC_EXT_HOLLOW_Y


def test_extended_jax_matches_pyref(dicts):
    d, da = dicts
    words, _, _ = corpus.build_corpus(n_words=400, seed=17)
    enc = corpus.encode_corpus(words)
    roots_jax, src_jax = stemmer.stem_batch(enc, da, extended=True)
    roots_jax, src_jax = np.asarray(roots_jax), np.asarray(src_jax)
    for i, w in enumerate(words):
        ref_root, ref_src = pyref.extract_root(enc[i], d, extended=True)
        got = tuple(int(c) for c in roots_jax[i] if c)
        assert got == ref_root, w
        assert int(src_jax[i]) == ref_src, w


def test_extended_improves_accuracy():
    from repro.core import accuracy
    words, truths, _ = corpus.build_corpus(n_words=2500, seed=19)
    d = corpus.build_dictionary()
    base = accuracy.evaluate(words, truths, d, infix=True)
    ext = accuracy.evaluate(words, truths, d, infix=True, extended=True)
    assert ext.accuracy > base.accuracy  # defective pasts now recovered
