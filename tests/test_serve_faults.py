"""Fault-tolerance tests: the deterministic fault-injection harness
(serve/faults.py) and every recovery path it drives — ring-slot retry
with backoff under injected dispatch failures, poison-pill bisection
quarantine, per-request deadlines, retire-side checksum verification
of corrupted device results, queue-cap admission control
(shed/raise/block), validated two-phase DictStore publishes with
rollback, and torn-checkpoint recovery in the corpus-index builder.
The recovery invariant throughout: every request that survives a fault
returns bit-identical results to a fault-free run."""
import itertools
import json
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, stemmer
from repro.index import builder
from repro.kernels import ops
from repro.serve import (DictStore, DictValidationError, Engine,
                         EngineUndrained, FailureInfo, FaultInjector,
                         FaultPlan, FaultSpec, InjectedFault, QueueFull,
                         StemmerWorkload, TextAnalysisWorkload,
                         validate_handle)


@pytest.fixture(scope="module")
def dict_and_words():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=256, seed=1)
    return arrays, corpus.encode_corpus(words)


@pytest.fixture(scope="module")
def baseline(dict_and_words):
    """Fault-free per-request roots for 8 x 32-word requests."""
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=2))
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(8)]
    assert eng.run_until_drained().drained
    return [np.array(eng.result(r).roots) for r in rids]


def _drain_8(arrays, enc, *, injector=None, **kw):
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=2, injector=injector, **kw))
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(8)]
    assert eng.run_until_drained().drained
    return eng, rids


# ---------------------------------------------------------------------------
# the injector itself
# ---------------------------------------------------------------------------
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="site"):
        FaultSpec("gpu")
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("dispatch", kind="corrupt")   # corrupt is retire-only
    with pytest.raises(ValueError, match="at"):
        FaultSpec("dispatch", at=-1)
    with pytest.raises(ValueError, match="count"):
        FaultSpec("retire", count=0)
    s = FaultSpec("dispatch", at=2, count=3)
    assert s.kind == "fail"                     # site default
    assert not s.covers(1) and s.covers(2) and s.covers(4)
    assert not s.covers(5)


def test_injector_is_deterministic(dict_and_words):
    """Same plan + same event sequence -> identical fired log and
    identical corruption (the retire rng is seeded per event)."""
    arrays, _ = dict_and_words
    plan = FaultPlan(specs=(FaultSpec("retire", at=0),), seed=42)
    outs = []
    for _ in range(2):
        inj = FaultInjector(plan)
        roots = np.arange(128, dtype=np.int32).reshape(32, 4)
        srcs = np.zeros(32, np.int32)
        r2, s2 = inj.on_retire(roots, srcs)
        outs.append((np.array(r2), inj.fired[:]))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1] == [("retire", "corrupt", 0)]
    assert not np.array_equal(outs[0][0],
                              np.arange(128, dtype=np.int32).reshape(32, 4))


# ---------------------------------------------------------------------------
# dispatch faults: retry, backoff, bisection quarantine
# ---------------------------------------------------------------------------
def test_dispatch_fault_mid_ring_bit_identical(dict_and_words, baseline):
    """An injected launch failure with max_inflight=2 is retried and the
    full drain stays bit-identical to the fault-free run."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=1),)))
    eng, rids = _drain_8(arrays, enc, injector=inj)
    assert inj.fired == [("dispatch", "fail", 1)]
    assert eng.workload.retries_total == 1
    for rid, want in zip(rids, baseline):
        req = eng.result(rid)
        assert req.failure is None
        np.testing.assert_array_equal(req.roots, want)


def test_repeated_dispatch_faults_with_backoff(dict_and_words, baseline):
    """Several injected failures in a row are absorbed while backoff is
    in effect; results stay bit-identical."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", count=2),)))
    eng, rids = _drain_8(arrays, enc, injector=inj, max_retries=3,
                         retry_backoff_s=0.01)
    assert eng.workload.retries_total == 2
    for rid, want in zip(rids, baseline):
        np.testing.assert_array_equal(eng.result(rid).roots, want)


def test_poison_pill_bisection_quarantine(dict_and_words, baseline):
    """Four requests coalesce into one tile; the one poisoned request is
    isolated by bisection and quarantined with a structured FailureInfo
    while the other three complete bit-identically."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(poison_rids=frozenset({2})))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=128,
                                 max_inflight=1, max_retries=1,
                                 injector=inj))
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(4)]
    assert eng.run_until_drained().drained
    w = eng.workload
    assert w.bisections >= 1 and w.quarantined == 1
    for i, rid in enumerate(rids):
        req = eng.result(rid)
        if i == 2:
            assert isinstance(req.failure, FailureInfo)
            assert req.failure.code == "quarantined"
            assert req.failure.rid == rid and req.failure.retries > 0
        else:
            assert req.failure is None
            np.testing.assert_array_equal(req.roots, baseline[i])


def test_strict_mode_propagates_first_failure(dict_and_words):
    """max_retries=0 restores the fail-fast contract: the injected
    launch failure reaches the caller, claims are unwound, and the
    engine still drains on retry."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=0),)))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_retries=0, injector=inj))
    eng.submit(enc[:32])
    with pytest.raises(InjectedFault):
        eng.step()
    assert all(r.dispatched == 0 for r in eng.workload.inflight)
    assert eng.run_until_drained().drained


# ---------------------------------------------------------------------------
# retire faults: checksum catches corrupted results
# ---------------------------------------------------------------------------
def test_tile_checksum_host_device_parity(dict_and_words):
    arrays, enc = dict_and_words
    roots, sources = stemmer.stem_batch(jnp.asarray(enc[:64]), arrays)
    dev = np.asarray(ops.tile_checksum(roots, sources, block_b=32))
    host = ops.tile_checksum_host(np.asarray(roots), np.asarray(sources),
                                  block_b=32)
    assert dev.shape == (2,)
    np.testing.assert_array_equal(dev, host)
    # a single flipped element changes the row checksum
    bad = np.array(roots)
    bad[5, 1] ^= 0x5A
    assert ops.tile_checksum_host(bad, np.asarray(sources),
                                  block_b=32)[0] != host[0]


def test_retire_corruption_detected_and_retried(dict_and_words, baseline):
    """An injected device-result corruption is caught by the retire-side
    checksum, the tile redispatches, and the drain is bit-identical."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("retire", at=0),)))
    eng, rids = _drain_8(arrays, enc, injector=inj)
    assert eng.workload.checksum_failures == 1
    assert eng.workload.retries_total == 1
    for rid, want in zip(rids, baseline):
        req = eng.result(rid)
        assert req.failure is None
        np.testing.assert_array_equal(req.roots, want)


def test_retire_corruption_strict_mode_raises(dict_and_words):
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("retire", at=0),)))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_retries=0, injector=inj))
    eng.submit(enc[:32])
    with pytest.raises(RuntimeError, match="checksum"):
        eng.run_until_drained()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_expired_request_fails_later_succeed(dict_and_words,
                                                      baseline):
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32))
    rid_dead = eng.submit(enc[:32], deadline_s=0.001)
    time.sleep(0.01)
    rid_live = eng.submit(enc[32:64])
    assert eng.run_until_drained().drained
    dead = eng.result(rid_dead)
    assert dead.failure is not None and dead.failure.code == "deadline"
    live = eng.result(rid_live)
    assert live.failure is None
    np.testing.assert_array_equal(live.roots, baseline[1])


def test_deadline_far_future_never_fires(dict_and_words, baseline):
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32))
    rid = eng.submit(enc[:32], deadline_s=3600.0)
    assert eng.run_until_drained().drained
    assert eng.result(rid).failure is None
    np.testing.assert_array_equal(eng.result(rid).roots, baseline[0])


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_queue_cap_validation(dict_and_words):
    arrays, _ = dict_and_words
    w = StemmerWorkload(DictStore(arrays), block_b=32)
    with pytest.raises(ValueError, match="on_full"):
        Engine(w, queue_cap=2, on_full="explode")
    with pytest.raises(ValueError, match="queue_cap"):
        Engine(w, queue_cap=0)
    with pytest.raises(ValueError, match="queue_cap"):
        Engine(w, on_full="shed")   # a cap-less queue is never full


def test_queue_cap_raise(dict_and_words):
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32),
                 queue_cap=1, on_full="raise")
    eng.submit(enc[:32])
    with pytest.raises(QueueFull):
        eng.submit(enc[:32])
    assert eng.run_until_drained().drained      # admitted work unaffected


def test_queue_cap_shed(dict_and_words, baseline):
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32),
                 queue_cap=2, on_full="shed")
    rids = [eng.submit(enc[:32]) for _ in range(5)]
    shed = [r for r in rids if eng.result(r) is not None
            and eng.result(r).failure is not None]
    assert len(shed) == 3 and eng.shed == 3
    for r in shed:
        assert eng.result(r).failure.code == "shed"
    assert eng.run_until_drained().drained
    served = [r for r in rids if r not in shed]
    for r in served:
        np.testing.assert_array_equal(eng.result(r).roots, baseline[0])


def test_queue_cap_block(dict_and_words, baseline):
    """on_full="block" ticks the engine inside submit until the request
    fits; every submission is eventually served."""
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32),
                 queue_cap=1, on_full="block")
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(4)]
    assert eng.run_until_drained().drained and eng.shed == 0
    for rid, want in zip(rids, baseline):
        np.testing.assert_array_equal(eng.result(rid).roots, want)


def test_undrained_raise_cancels_and_engine_reusable(dict_and_words,
                                                     baseline):
    """A poisoned request that would never drain is cancelled by
    on_undrained="raise" and the engine serves fresh work afterwards."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(poison_rids=frozenset({0})))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_retries=50, retry_backoff_s=0.01,
                                 injector=inj))
    eng.submit(enc[:32])
    with pytest.raises(EngineUndrained) as exc:
        eng.run_until_drained(max_ticks=3)
    assert exc.value.report.cancelled == [0]
    assert eng.result(0).failure.code == "cancelled"
    assert not eng.queue and eng.workload.active == 0
    rid = eng.submit(enc[32:64])
    assert eng.run_until_drained().drained
    np.testing.assert_array_equal(eng.result(rid).roots, baseline[1])


# ---------------------------------------------------------------------------
# text workload inherits the whole fault path
# ---------------------------------------------------------------------------
def test_text_workload_dispatch_fault_and_failed_read(dict_and_words):
    arrays, _ = dict_and_words
    docs = ["كتب الولد درسا", "ذهب الرجل الى السوق"]
    ref = Engine(TextAnalysisWorkload(DictStore(arrays), block_b=32,
                                      frontend="host"))
    ref_rids = [ref.submit(d) for d in docs]
    assert ref.run_until_drained().drained
    want = [ref.result(r).analyses() for r in ref_rids]

    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=0),)))
    eng = Engine(TextAnalysisWorkload(DictStore(arrays), block_b=32,
                                      frontend="host", injector=inj))
    rids = [eng.submit(d) for d in docs]
    assert eng.run_until_drained().drained
    assert eng.workload.retries_total == 1
    assert [eng.result(r).analyses() for r in rids] == want

    # a quarantined text request refuses to hand out garbage analyses
    inj2 = FaultInjector(FaultPlan(poison_rids=frozenset({0})))
    eng2 = Engine(TextAnalysisWorkload(DictStore(arrays), block_b=32,
                                       frontend="host", max_retries=1,
                                       injector=inj2))
    rid = eng2.submit(docs[0])
    assert eng2.run_until_drained().drained
    req = eng2.result(rid)
    assert req.failure.code == "quarantined"
    with pytest.raises(RuntimeError, match="quarantined"):
        req.analyses()


# ---------------------------------------------------------------------------
# DictStore: two-phase publish, injected rejection, rollback
# ---------------------------------------------------------------------------
def test_publish_validation_rejects_bad_tables(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays)
    v0 = store.version
    bad = stemmer.RootDictArrays(
        tri=np.array([5, 3, 1], np.int32),          # unsorted
        quad=np.asarray(arrays.quad), bi=np.asarray(arrays.bi))
    with pytest.raises(DictValidationError, match="sorted"):
        store.publish(bad)
    assert store.version == v0                      # phase 2 never ran
    dup = stemmer.RootDictArrays(
        tri=np.array([3, 3], np.int32),
        quad=np.asarray(arrays.quad), bi=np.asarray(arrays.bi))
    with pytest.raises(DictValidationError):
        store.publish(dup)
    neg = stemmer.RootDictArrays(
        tri=np.array([-7, 3], np.int32),
        quad=np.asarray(arrays.quad), bi=np.asarray(arrays.bi))
    with pytest.raises(DictValidationError, match="negative"):
        store.publish(neg)
    validate_handle(store.acquire().handle)         # current is valid


def test_publish_injected_rejection_and_rollback(dict_and_words):
    arrays, _ = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("publish", at=0),)))
    store = DictStore(arrays, keep_history=True, injector=inj)
    v0 = store.acquire().version
    d2 = corpus.build_dictionary(n_tri=150, n_quad=20, seed=7)
    a2 = stemmer.RootDictArrays.from_rootdict(d2)
    with pytest.raises(InjectedFault):
        store.publish(a2)
    assert store.acquire().version == v0            # still serving v0
    v1 = store.publish(a2)                          # next publish lands
    assert v1 > v0
    v2 = store.rollback(v0)
    assert v2 > v1                                  # versions stay monotone
    np.testing.assert_array_equal(
        np.asarray(store.acquire().handle.arrays.tri),
        np.asarray(store.get(v0).handle.arrays.tri))


def test_rollback_requires_history(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays, keep_history=False)
    d2 = corpus.build_dictionary(n_tri=150, n_quad=20, seed=7)
    store.publish(stemmer.RootDictArrays.from_rootdict(d2))
    with pytest.raises(KeyError):
        store.rollback(0)


# ---------------------------------------------------------------------------
# index builder: torn checkpoints, chunk retry
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def index_setup():
    table = corpus.build_token_table(forms_per_root=6)
    d = corpus.build_dictionary(n_tri=300, n_quad=40, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)

    def stream():
        return corpus.stream_corpus_words(9000, seed=3, chunk_words=4096,
                                          table=table)

    ref = builder.build_corpus_index(stream(), arrays, block_b=512,
                                     block_w=512)
    return arrays, stream, ref


def _assert_same_index(got, want):
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(want.counts))
    np.testing.assert_array_equal(np.asarray(got.docs),
                                  np.asarray(want.docs))
    np.testing.assert_array_equal(np.asarray(got.positions),
                                  np.asarray(want.positions))


def test_build_under_checkpoint_and_compute_faults(index_setup, tmp_path):
    """A torn checkpoint write and a failed chunk compute are both
    retried in-build; the result is bit-identical and the manifest
    records a content hash per chunk."""
    arrays, stream, ref = index_setup
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("checkpoint", at=1),
                                         FaultSpec("dispatch", at=1))))
    idx = builder.build_corpus_index(stream(), arrays,
                                     checkpoint_dir=str(tmp_path),
                                     block_b=512, block_w=512,
                                     injector=inj)
    assert len(inj.fired) == 2
    _assert_same_index(idx, ref)
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["schema"] == builder.MANIFEST_SCHEMA
    for rec in man["chunks"]:
        assert isinstance(rec["sha"], str) and len(rec["sha"]) == 16


def test_torn_partial_on_resume_recomputed(index_setup, tmp_path):
    """A partial torn on disk between runs fails its manifest hash check
    and is transparently recomputed on resume — bit-identical result."""
    arrays, stream, ref = index_setup
    ckpt = str(tmp_path / "ckpt")
    builder.build_corpus_index(itertools.islice(stream(), 2), arrays,
                               checkpoint_dir=ckpt, block_b=512,
                               block_w=512)
    parts = sorted(p for p in os.listdir(ckpt) if p.endswith(".npz"))
    assert len(parts) == 2
    torn = os.path.join(ckpt, parts[1])
    with open(torn, "r+b") as f:
        f.truncate(os.path.getsize(torn) // 2)
    resumed = builder.build_corpus_index(stream(), arrays,
                                         checkpoint_dir=ckpt, resume=True,
                                         block_b=512, block_w=512)
    _assert_same_index(resumed, ref)
    # and the manifest now carries the recomputed chunk's fresh hash
    man = json.loads((tmp_path / "ckpt" / "manifest.json").read_text())
    assert man["chunks"][1]["sha"] == builder._file_sha(torn)


def test_chunk_compute_fault_exhaustion_raises(index_setup, tmp_path):
    arrays, stream, _ = index_setup
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", count=99),)))
    with pytest.raises(RuntimeError):
        builder.build_corpus_index(stream(), arrays,
                                   checkpoint_dir=str(tmp_path),
                                   block_b=512, block_w=512,
                                   injector=inj, chunk_retries=1)
