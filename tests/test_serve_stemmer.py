"""Serving-core tests: the workload-agnostic Engine, StemmerWorkload
tile coalescing + bit-exact parity across dispatch ring depths
(including across a dictionary hot swap, and one landing while tiles
are in flight), the dispatch/retire pipeline's tick accounting,
DictStore versioning + sorted-merge delta publishes, resolved-dict
re-trace avoidance, and the drain report / undrained-work surfacing.
Multi-device (sharded super-tile) coverage lives in
test_serve_sharded.py under forced host devices."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, pyref, stemmer
from repro.kernels import stem_fused as sf
from repro.serve import (DictStore, DrainReport, Engine, EngineUndrained,
                         StemmerWorkload, Workload)


@pytest.fixture(scope="module")
def dict_and_words():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=200, seed=1)
    return arrays, corpus.encode_corpus(words)


def _serve(store, enc, sizes, *, block_b=32, steps_before_swap=None,
           swap_to=None, max_inflight=2, max_requests=None):
    """Submit word batches of the given sizes, optionally hot-swap, drain."""
    eng = Engine(StemmerWorkload(store, block_b=block_b,
                                 max_inflight=max_inflight,
                                 max_requests=max_requests))
    off, rids = 0, []
    for n in sizes:
        rids.append(eng.submit(enc[off:off + n]))
        off += n
    if steps_before_swap is not None:
        for _ in range(steps_before_swap):
            eng.step()
        store.publish(swap_to)
    rep = eng.run_until_drained()
    assert rep.drained
    return eng, rids, rep


# ---------------------------------------------------------------------------
# StemmerWorkload parity + coalescing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("max_inflight", [1, 2, 4])
def test_serve_parity_bit_identical(dict_and_words, max_inflight):
    """Bit-exact at every dispatch ring depth: 1 (synchronous tick,
    overlap off) through deep overlapped rings."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    sizes = (37, 64, 5, 50)  # deliberately not block_b-aligned
    eng, rids, rep = _serve(store, enc, sizes, block_b=32,
                            max_inflight=max_inflight)

    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:sum(sizes)]), arrays)
    want_r, want_s = np.asarray(want_r), np.asarray(want_s)
    off = 0
    for rid, n in zip(rids, sizes):
        req = eng.result(rid)
        assert req.done and req.n_words == n
        np.testing.assert_array_equal(req.roots, want_r[off:off + n])
        np.testing.assert_array_equal(req.sources, want_s[off:off + n])
        assert (req.dict_versions == 0).all()
        assert req.dict_version == 0
        off += n


def test_serve_coalesces_across_requests(dict_and_words):
    """Many small requests share tiles: ticks == ceil(total / block_b),
    not one tick per request."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    sizes = (10,) * 13  # 130 words
    eng, rids, rep = _serve(store, enc, sizes, block_b=32)
    assert eng.workload.ticks_launched == -(-130 // 32)  # 5 tiles
    assert all(eng.result(r).done for r in rids)


def test_serve_empty_request_completes(dict_and_words):
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16))
    rid_empty = eng.submit(np.zeros((0, 16), np.int32))
    rid_real = eng.submit(enc[:8])
    rep = eng.run_until_drained()
    assert rep.drained
    req = eng.result(rid_empty)
    assert req.done and req.n_words == 0 and req.dict_version is None
    assert eng.result(rid_real).done


def test_serve_accepts_raw_strings(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16))
    words, _, _ = corpus.build_corpus(n_words=10, seed=3)
    rid = eng.submit(words)  # list[str] encodes through alphabet
    eng.run_until_drained()
    req = eng.result(rid)
    want_r, _ = stemmer.stem_batch(
        jnp.asarray(corpus.encode_corpus(words)), arrays)
    np.testing.assert_array_equal(req.roots, np.asarray(want_r))


def test_stemmer_workload_satisfies_protocol(dict_and_words):
    arrays, _ = dict_and_words
    assert isinstance(StemmerWorkload(DictStore(arrays)), Workload)


# ---------------------------------------------------------------------------
# dispatch/retire ring (overlapped serving)
# ---------------------------------------------------------------------------
def test_tick_dispatches_until_ring_full(dict_and_words):
    """One engine tick must keep launching tiles until max_inflight
    launches are outstanding — not one tile per tick (the pre-async
    coalescing bug)."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16, max_inflight=4))
    for i in range(10):
        eng.submit(enc[i * 16:(i + 1) * 16])   # 10 tiles pending
    eng.step()
    w = eng.workload
    assert w.ticks_launched == 4               # ring filled in ONE tick
    assert len(w.ring) + len(w._free_slots) == 4


def test_ticks_to_drain_shrink_with_ring_depth(dict_and_words):
    """Deeper rings drain the same workload in fewer engine ticks, with
    the launch count invariant (regression for the one-tile-per-tick
    coalescing)."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    ticks, launches = {}, {}
    for depth in (1, 4):
        eng = Engine(StemmerWorkload(store, block_b=16, max_inflight=depth))
        for i in range(10):                    # 160 words -> 10 tiles
            eng.submit(enc[i * 16:(i + 1) * 16])
        rep = eng.run_until_drained()
        assert rep.drained
        ticks[depth] = rep.ticks
        launches[depth] = eng.workload.ticks_launched
    assert launches[1] == launches[4] == 10
    assert ticks[4] < ticks[1]


def test_staging_buffers_reused_across_ticks(dict_and_words):
    """Dispatch fills a preallocated per-slot staging buffer; no per-tick
    tile allocation."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    w = StemmerWorkload(store, block_b=16, max_inflight=2)
    eng = Engine(w)
    buffers = {id(b) for b in w._staging}
    assert len(buffers) == 2
    for i in range(8):
        eng.submit(enc[i * 16:(i + 1) * 16])
    eng.run_until_drained()
    assert {id(b) for b in w._staging} == buffers  # same arrays throughout
    assert w._free_slots and len(w._free_slots) == 2  # all slots returned


def test_trickle_feed_keeps_launches_in_flight(dict_and_words):
    """A tick that dispatched (or retired) something never hard-syncs
    the ring: a server alternating submit()/step() keeps overlap even
    though the queue empties between requests."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16, max_inflight=2))
    w = eng.workload
    for i in range(3):                  # one tile per request, trickled
        eng.submit(enc[i * 16:(i + 1) * 16])
        eng.step()
        # the just-dispatched launch stays in flight — no drain sync
        assert w.ring, f"step {i}: ring drained despite fresh dispatch"
    rep = eng.run_until_drained()
    assert rep.drained and w.ticks_launched == 3
    want_r, _ = stemmer.stem_batch(jnp.asarray(enc[:48]), arrays)
    got_r = np.concatenate([eng.result(r).roots for r in range(3)])
    np.testing.assert_array_equal(got_r, np.asarray(want_r))


def test_failed_launch_leaves_engine_recoverable(dict_and_words,
                                                 monkeypatch):
    """A kernel launch that raises must not wedge the engine. In strict
    mode (max_retries=0) the exception propagates but the staging slot
    returns to the ring and the words stay undispatched, so the next
    tick retries and the engine still drains; with retries enabled
    (the default) the same failure is absorbed entirely."""
    from repro.kernels import ops

    arrays, enc = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16, max_inflight=2,
                                 max_retries=0))
    rids = [eng.submit(enc[i * 16:(i + 1) * 16]) for i in range(3)]

    real = ops.extract_roots_fused
    boom = {"armed": True}

    def flaky(*a, **kw):
        if boom.pop("armed", False):
            raise RuntimeError("transient device failure")
        return real(*a, **kw)

    monkeypatch.setattr(ops, "extract_roots_fused", flaky)
    with pytest.raises(RuntimeError, match="transient"):
        eng.step()
    w = eng.workload
    assert len(w._free_slots) == 2          # slot returned
    assert all(r.dispatched == 0 for r in w.inflight)  # nothing stranded
    rep = eng.run_until_drained()           # retry succeeds
    assert rep.drained
    want_r, _ = stemmer.stem_batch(jnp.asarray(enc[:48]), arrays)
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    np.testing.assert_array_equal(got_r, np.asarray(want_r))

    # default mode: the retry machinery absorbs the same transient
    # failure — no exception reaches the caller, results bit-identical
    eng2 = Engine(StemmerWorkload(store, block_b=16, max_inflight=2))
    rids2 = [eng2.submit(enc[i * 16:(i + 1) * 16]) for i in range(3)]
    boom["armed"] = True
    rep2 = eng2.run_until_drained()
    assert rep2.drained and eng2.workload.retries_total == 1
    got2 = np.concatenate([eng2.result(r).roots for r in rids2])
    np.testing.assert_array_equal(got2, np.asarray(want_r))


def test_overlap_parity_with_sync(dict_and_words):
    """Depth-4 overlapped serving returns exactly what the synchronous
    tick returns, request by request."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    sizes = (37, 64, 5, 50, 20)
    sync_eng, sync_rids, _ = _serve(store, enc, sizes, max_inflight=1)
    over_eng, over_rids, _ = _serve(store, enc, sizes, max_inflight=4)
    for rs, ro in zip(sync_rids, over_rids):
        a, b = sync_eng.result(rs), over_eng.result(ro)
        np.testing.assert_array_equal(a.roots, b.roots)
        np.testing.assert_array_equal(a.sources, b.sources)
        np.testing.assert_array_equal(a.dict_versions, b.dict_versions)


# ---------------------------------------------------------------------------
# dictionary hot swap
# ---------------------------------------------------------------------------
def test_hot_swap_mid_stream_bit_identical(dict_and_words):
    """A publish() between ticks is picked up by the next tile launch;
    responses carry the version that served each word, and every word is
    bit-identical to stem_batch under that version's arrays."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    sizes = (30, 30, 30, 30, 30)
    eng, rids, _ = _serve(store, enc, sizes, block_b=32,
                          steps_before_swap=2, swap_to=grown)

    versions = np.concatenate([eng.result(r).dict_versions for r in rids])
    assert set(versions.tolist()) == {0, 1}  # swap landed mid-stream
    all_words = enc[:sum(sizes)]
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    got_s = np.concatenate([eng.result(r).sources for r in rids])
    for v in (0, 1):
        mask = versions == v
        want_r, want_s = stemmer.stem_batch(jnp.asarray(all_words[mask]),
                                            store.get(v).arrays)
        np.testing.assert_array_equal(got_r[mask], np.asarray(want_r))
        np.testing.assert_array_equal(got_s[mask], np.asarray(want_s))
    # a request straddling the swap reports the version of its last word
    straddlers = [eng.result(r) for r in rids
                  if len(set(eng.result(r).dict_versions.tolist())) > 1]
    assert straddlers
    for req in straddlers:
        assert req.dict_version == int(req.dict_versions[-1]) == 1


def test_same_shape_swap_replays_jit_trace(dict_and_words):
    """A hot swap whose arrays keep their shapes must not re-trace the
    megakernel: the DictStore's pre-resolved handle pins the static
    config, so the jit cache is hit."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    _serve(store, enc, (40,), block_b=32)
    before = sf.stem_fused_pallas._cache_size()

    shifted = stemmer.RootDictArrays(tri=arrays.tri + 1, quad=arrays.quad + 1,
                                     bi=arrays.bi + 1)  # same shapes, sorted
    store.publish(shifted)
    eng, rids, _ = _serve(store, enc, (40,), block_b=32)
    assert sf.stem_fused_pallas._cache_size() == before
    # and the swapped dictionary really was used
    want_r, _ = stemmer.stem_batch(jnp.asarray(enc[:40]), shifted)
    np.testing.assert_array_equal(eng.result(rids[0]).roots,
                                  np.asarray(want_r))


def test_swap_while_tile_in_flight_pins_dispatch_version(dict_and_words):
    """A publish() landing between a tile's dispatch and its retire must
    not relabel (or re-serve) that tile: every word records the version
    acquired at dispatch, exactly."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    grown = corpus.grow_root_arrays(arrays, 2048, seed=9)
    eng = Engine(StemmerWorkload(store, block_b=16, max_inflight=4))
    rids = [eng.submit(enc[i * 16:(i + 1) * 16]) for i in range(8)]
    eng.step()                      # fills the ring: 4 tiles in flight
    w = eng.workload
    assert w.ticks_launched == 4 and len(w.ring) + len(w._free_slots) == 4
    in_flight_words = sum(r.dispatched for r in w.inflight)
    served_words = sum(r.served for r in w.inflight)
    assert in_flight_words == 64    # dispatched under v0 ...
    assert served_words < 64        # ... not yet all retired
    v1 = store.publish(grown)
    rep = eng.run_until_drained()
    assert rep.drained and v1 == 1

    versions = np.concatenate([eng.result(r).dict_versions for r in rids])
    # tiles in flight at publish time keep the version they dispatched
    # under; only post-swap dispatches see v1
    np.testing.assert_array_equal(versions[:64], 0)
    np.testing.assert_array_equal(versions[64:], 1)
    # and each half is bit-identical to stem_batch under its own version
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    for v, sl in ((0, slice(0, 64)), (1, slice(64, 128))):
        want_r, _ = stemmer.stem_batch(jnp.asarray(enc[sl]),
                                       store.get(v).arrays)
        np.testing.assert_array_equal(got_r[sl], np.asarray(want_r))


# ---------------------------------------------------------------------------
# DictStore
# ---------------------------------------------------------------------------
def test_dict_store_versioning(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays)
    assert store.version == 0
    assert store.acquire().version == 0
    assert store.acquire().handle.residency in ("resident", "streamed")

    snapshot = store.acquire()  # held across a publish -> unchanged
    grown = corpus.grow_root_arrays(arrays, 2048, seed=5)
    assert store.publish(grown) == 1
    assert store.version == 1
    assert snapshot.version == 0
    assert store.get(0).n_keys == arrays.n_keys
    assert store.get(1).n_keys > store.get(0).n_keys
    with pytest.raises(KeyError, match="version 9"):
        store.get(9)

    # raw pyref.RootDict publishes pack through from_rootdict
    d = corpus.build_dictionary(n_tri=50, n_quad=10, seed=2)
    assert isinstance(d, pyref.RootDict)
    assert store.publish(d) == 2
    assert store.get(2).arrays.tri.shape[0] > 0

    no_hist = DictStore(arrays, keep_history=False)
    no_hist.publish(grown)
    with pytest.raises(KeyError):
        no_hist.get(0)


def test_publish_delta_sorted_merge(dict_and_words):
    """publish_delta merges insert/remove key lists against the current
    version: equivalent to a from-scratch publish of the merged table,
    with untouched tables sharing the current version's device arrays."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    tri0 = np.asarray(arrays.tri)
    removed = tri0[[0, 3, 11]].tolist()
    inserted = [int(tri0.max() + d) for d in (2, 7, 5)]
    v1 = store.publish_delta(insert={"tri": inserted + [int(tri0[1])]},
                             remove={"tri": removed})
    assert v1 == 1
    a1 = store.get(1).arrays
    want_tri = np.union1d(np.setdiff1d(tri0, removed),
                          np.asarray(inserted, np.int32))
    np.testing.assert_array_equal(np.asarray(a1.tri), want_tri)
    # untouched tables are the same device buffers, not re-uploads
    assert a1.quad is arrays.quad and a1.bi is arrays.bi

    # served output equals a from-scratch publish of the merged arrays
    scratch = stemmer.RootDictArrays(tri=jnp.asarray(want_tri),
                                     quad=arrays.quad, bi=arrays.bi)
    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:64]), scratch)
    eng, rids, _ = _serve(store, enc, (64,))
    np.testing.assert_array_equal(eng.result(rids[0]).roots,
                                  np.asarray(want_r))
    np.testing.assert_array_equal(eng.result(rids[0]).sources,
                                  np.asarray(want_s))
    assert eng.result(rids[0]).dict_version == 1


def test_publish_delta_validates(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays)
    with pytest.raises(ValueError, match="absent"):
        store.publish_delta(remove={"tri": [1 << 23]})
    with pytest.raises(ValueError, match="both"):
        store.publish_delta(insert={"tri": [7]}, remove={"tri": [7]})
    with pytest.raises(ValueError, match="unknown dictionary tables"):
        store.publish_delta(insert={"pent": [7]})
    assert store.version == 0       # failed deltas publish nothing

    # raw root strings encode + pack through the alphabet
    from repro.core import alphabet as ab
    root = "كتب"
    key = ab.pack_key(ab.encode_word(root))
    v_str = store.publish_delta(insert={"tri": [root]})
    assert key in np.asarray(store.get(v_str).arrays.tri)

    # removing every bi key leaves the empty-table sentinel, and the
    # table can be refilled later
    bi0 = np.asarray(arrays.bi)
    bi0 = bi0[bi0 >= 0]
    v = store.publish_delta(remove={"bi": bi0.tolist()})
    np.testing.assert_array_equal(np.asarray(store.get(v).arrays.bi), [-1])
    v2 = store.publish_delta(insert={"bi": bi0[:3].tolist()})
    np.testing.assert_array_equal(np.asarray(store.get(v2).arrays.bi),
                                  np.sort(bi0[:3]))


# ---------------------------------------------------------------------------
# drain reporting (Engine-level, workload-independent)
# ---------------------------------------------------------------------------
def test_run_until_drained_surfaces_unfinished(dict_and_words):
    arrays, enc = dict_and_words
    store = DictStore(arrays)

    # "return" policy hands back the report and leaves the engine resumable
    eng = Engine(StemmerWorkload(store, block_b=16))
    rids = [eng.submit(enc[:40]), eng.submit(enc[40:80])]
    partial = eng.run_until_drained(max_ticks=1,  # 80 words need 5 ticks
                                    on_undrained="return")
    assert isinstance(partial, DrainReport) and not partial.drained
    assert partial.ticks == 1 and partial.pending
    final = eng.run_until_drained()
    assert final.drained and final.pending == []
    assert all(eng.result(r).done and eng.result(r).failure is None
               for r in rids)
    with pytest.raises(ValueError, match="on_undrained"):
        eng.run_until_drained(on_undrained="ignore")

    # "raise" policy cancels the stranded requests — each lands in the
    # finished table with FailureInfo("cancelled") — so the engine is
    # empty and reusable afterwards, not wedged mid-drain
    eng2 = Engine(StemmerWorkload(store, block_b=16))
    rids2 = [eng2.submit(enc[:40]), eng2.submit(enc[40:80])]
    with pytest.raises(EngineUndrained) as exc:
        eng2.run_until_drained(max_ticks=1)
    report = exc.value.report
    assert not report.drained and report.ticks == 1
    assert set(report.pending) == set(rids2)
    assert set(report.cancelled) == set(rids2)
    for r in rids2:
        req = eng2.result(r)
        assert req.done and req.failure.code == "cancelled"
    assert not eng2.queue and eng2.workload.active == 0
    rid3 = eng2.submit(enc[:16])            # fresh work still serves
    assert eng2.run_until_drained().drained
    want_r, _ = stemmer.stem_batch(jnp.asarray(enc[:16]), arrays)
    np.testing.assert_array_equal(eng2.result(rid3).roots,
                                  np.asarray(want_r))
