"""Serving-core tests: the workload-agnostic Engine, StemmerWorkload
tile coalescing + bit-exact parity (including across a dictionary hot
swap), DictStore versioning, resolved-dict re-trace avoidance, and the
drain report / undrained-work surfacing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, pyref, stemmer
from repro.kernels import stem_fused as sf
from repro.serve import (DictStore, DrainReport, Engine, EngineUndrained,
                         StemmerWorkload, Workload)


@pytest.fixture(scope="module")
def dict_and_words():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=200, seed=1)
    return arrays, corpus.encode_corpus(words)


def _serve(store, enc, sizes, *, block_b=32, steps_before_swap=None,
           swap_to=None, max_inflight=None):
    """Submit word batches of the given sizes, optionally hot-swap, drain."""
    eng = Engine(StemmerWorkload(store, block_b=block_b,
                                 max_inflight=max_inflight))
    off, rids = 0, []
    for n in sizes:
        rids.append(eng.submit(enc[off:off + n]))
        off += n
    if steps_before_swap is not None:
        for _ in range(steps_before_swap):
            eng.step()
        store.publish(swap_to)
    rep = eng.run_until_drained()
    assert rep.drained
    return eng, rids, rep


# ---------------------------------------------------------------------------
# StemmerWorkload parity + coalescing
# ---------------------------------------------------------------------------
def test_serve_parity_bit_identical(dict_and_words):
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    sizes = (37, 64, 5, 50)  # deliberately not block_b-aligned
    eng, rids, rep = _serve(store, enc, sizes, block_b=32)

    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:sum(sizes)]), arrays)
    want_r, want_s = np.asarray(want_r), np.asarray(want_s)
    off = 0
    for rid, n in zip(rids, sizes):
        req = eng.result(rid)
        assert req.done and req.n_words == n
        np.testing.assert_array_equal(req.roots, want_r[off:off + n])
        np.testing.assert_array_equal(req.sources, want_s[off:off + n])
        assert (req.dict_versions == 0).all()
        assert req.dict_version == 0
        off += n


def test_serve_coalesces_across_requests(dict_and_words):
    """Many small requests share tiles: ticks == ceil(total / block_b),
    not one tick per request."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    sizes = (10,) * 13  # 130 words
    eng, rids, rep = _serve(store, enc, sizes, block_b=32)
    assert eng.workload.ticks_launched == -(-130 // 32)  # 5 tiles
    assert all(eng.result(r).done for r in rids)


def test_serve_empty_request_completes(dict_and_words):
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16))
    rid_empty = eng.submit(np.zeros((0, 16), np.int32))
    rid_real = eng.submit(enc[:8])
    rep = eng.run_until_drained()
    assert rep.drained
    req = eng.result(rid_empty)
    assert req.done and req.n_words == 0 and req.dict_version is None
    assert eng.result(rid_real).done


def test_serve_accepts_raw_strings(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16))
    words, _, _ = corpus.build_corpus(n_words=10, seed=3)
    rid = eng.submit(words)  # list[str] encodes through alphabet
    eng.run_until_drained()
    req = eng.result(rid)
    want_r, _ = stemmer.stem_batch(
        jnp.asarray(corpus.encode_corpus(words)), arrays)
    np.testing.assert_array_equal(req.roots, np.asarray(want_r))


def test_stemmer_workload_satisfies_protocol(dict_and_words):
    arrays, _ = dict_and_words
    assert isinstance(StemmerWorkload(DictStore(arrays)), Workload)


# ---------------------------------------------------------------------------
# dictionary hot swap
# ---------------------------------------------------------------------------
def test_hot_swap_mid_stream_bit_identical(dict_and_words):
    """A publish() between ticks is picked up by the next tile launch;
    responses carry the version that served each word, and every word is
    bit-identical to stem_batch under that version's arrays."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    sizes = (30, 30, 30, 30, 30)
    eng, rids, _ = _serve(store, enc, sizes, block_b=32,
                          steps_before_swap=2, swap_to=grown)

    versions = np.concatenate([eng.result(r).dict_versions for r in rids])
    assert set(versions.tolist()) == {0, 1}  # swap landed mid-stream
    all_words = enc[:sum(sizes)]
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    got_s = np.concatenate([eng.result(r).sources for r in rids])
    for v in (0, 1):
        mask = versions == v
        want_r, want_s = stemmer.stem_batch(jnp.asarray(all_words[mask]),
                                            store.get(v).arrays)
        np.testing.assert_array_equal(got_r[mask], np.asarray(want_r))
        np.testing.assert_array_equal(got_s[mask], np.asarray(want_s))
    # a request straddling the swap reports the version of its last word
    straddlers = [eng.result(r) for r in rids
                  if len(set(eng.result(r).dict_versions.tolist())) > 1]
    assert straddlers
    for req in straddlers:
        assert req.dict_version == int(req.dict_versions[-1]) == 1


def test_same_shape_swap_replays_jit_trace(dict_and_words):
    """A hot swap whose arrays keep their shapes must not re-trace the
    megakernel: the DictStore's pre-resolved handle pins the static
    config, so the jit cache is hit."""
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    _serve(store, enc, (40,), block_b=32)
    before = sf.stem_fused_pallas._cache_size()

    shifted = stemmer.RootDictArrays(tri=arrays.tri + 1, quad=arrays.quad + 1,
                                     bi=arrays.bi + 1)  # same shapes, sorted
    store.publish(shifted)
    eng, rids, _ = _serve(store, enc, (40,), block_b=32)
    assert sf.stem_fused_pallas._cache_size() == before
    # and the swapped dictionary really was used
    want_r, _ = stemmer.stem_batch(jnp.asarray(enc[:40]), shifted)
    np.testing.assert_array_equal(eng.result(rids[0]).roots,
                                  np.asarray(want_r))


# ---------------------------------------------------------------------------
# DictStore
# ---------------------------------------------------------------------------
def test_dict_store_versioning(dict_and_words):
    arrays, _ = dict_and_words
    store = DictStore(arrays)
    assert store.version == 0
    assert store.acquire().version == 0
    assert store.acquire().handle.residency in ("resident", "streamed")

    snapshot = store.acquire()  # held across a publish -> unchanged
    grown = corpus.grow_root_arrays(arrays, 2048, seed=5)
    assert store.publish(grown) == 1
    assert store.version == 1
    assert snapshot.version == 0
    assert store.get(0).n_keys == arrays.n_keys
    assert store.get(1).n_keys > store.get(0).n_keys
    with pytest.raises(KeyError, match="version 9"):
        store.get(9)

    # raw pyref.RootDict publishes pack through from_rootdict
    d = corpus.build_dictionary(n_tri=50, n_quad=10, seed=2)
    assert isinstance(d, pyref.RootDict)
    assert store.publish(d) == 2
    assert store.get(2).arrays.tri.shape[0] > 0

    no_hist = DictStore(arrays, keep_history=False)
    no_hist.publish(grown)
    with pytest.raises(KeyError):
        no_hist.get(0)


# ---------------------------------------------------------------------------
# drain reporting (Engine-level, workload-independent)
# ---------------------------------------------------------------------------
def test_run_until_drained_surfaces_unfinished(dict_and_words):
    arrays, enc = dict_and_words
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16))
    rids = [eng.submit(enc[:40]), eng.submit(enc[40:80])]

    with pytest.raises(EngineUndrained) as exc:
        eng.run_until_drained(max_ticks=1)  # 80 words need 5 ticks
    report = exc.value.report
    assert not report.drained and report.ticks == 1
    assert set(report.pending) == set(rids)

    # "return" policy hands back the report and leaves the engine resumable
    partial = eng.run_until_drained(max_ticks=1, on_undrained="return")
    assert isinstance(partial, DrainReport) and not partial.drained
    final = eng.run_until_drained()
    assert final.drained and final.pending == []
    assert all(eng.result(r).done for r in rids)
    with pytest.raises(ValueError, match="on_undrained"):
        eng.run_until_drained(on_undrained="ignore")
