"""Shared fixtures for the tier-1 suite."""
import pytest


@pytest.fixture(autouse=True)
def _reset_dispatch_count():
    """Zero ops.dispatch_count() around every test.

    The counter is process-global, so without this a test that asserts
    launch counts would see whatever the previously-run module left
    behind — pass/fail would depend on collection order.
    """
    from repro.kernels import ops

    ops.reset_dispatch_count()
    yield
    ops.reset_dispatch_count()
