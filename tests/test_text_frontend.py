"""Text front-end kernel: segmentation properties and fused-chain parity.

The satellite property tests run twice: once hypothesis-driven (skipped
when hypothesis is absent — it is not in the pinned image) and once as
an exhaustive small-grid sweep that needs no extra dependency: every
string over a 6-symbol alphabet up to length 4, coalesced into ONE tile
and pushed through the kernel in a single launch, compared per-document
against the host reference."""
import itertools

import numpy as np
import pytest

from repro.core import corpus, stemmer
from repro.core import textnorm as tn
from repro.kernels import ops
from repro.kernels import text_frontend as tf


def _pad(chars: np.ndarray, block: int = 128) -> np.ndarray:
    t = max(block, -(-chars.shape[0] // block) * block)
    tile = np.zeros(t, np.int32)
    tile[:chars.shape[0]] = chars
    return tile


def _expected(docs):
    """Host reference over coalesced docs: concatenated word rows plus
    tile-absolute byte spans."""
    _, _, byte_off = tn.coalesce_docs(docs)
    rows, spans = [], []
    for off, doc in zip(byte_off, docs):
        w, s = tn.analyze_text_py(doc)
        rows.append(w)
        spans.append(s + off)
    return (np.concatenate(rows) if rows else np.zeros((0, 16), np.int32),
            np.concatenate(spans) if spans else np.zeros((0, 2), np.int64))


def _run_tile(tile, block_w=128):
    words_j, geo = tn.frontend_reference(tile, block_w=block_w)
    words_k = tf.text_frontend_pallas(tile, geo.starts, geo.lens,
                                      block_w=block_w, interpret=True)
    np.testing.assert_array_equal(np.asarray(words_k), np.asarray(words_j))
    n = int(geo.n_words)
    return np.asarray(words_j)[:n], np.asarray(geo.spans)[:n]


# ---------------------------------------------------------------------------
# exhaustive small-grid sweep (the hypothesis-free fallback)
# ---------------------------------------------------------------------------
def test_exhaustive_small_grid_one_launch():
    # letters, a separator, a combining mark, Arabic punctuation
    symbols = ("ا", "ب", "ك", " ", "ّ", "،")
    docs = ["".join(p) for n in range(5)
            for p in itertools.product(symbols, repeat=n)]
    assert len(docs) == 1 + 6 + 36 + 216 + 1296
    chars, _, _ = tn.coalesce_docs(docs)
    got_w, got_s = _run_tile(_pad(chars))
    want_w, want_s = _expected(docs)
    np.testing.assert_array_equal(got_w, want_w)
    np.testing.assert_array_equal(got_s, want_s)


# ---------------------------------------------------------------------------
# hypothesis-driven variant (skipped when the package is absent)
# ---------------------------------------------------------------------------
def test_hypothesis_random_documents():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    alphabet = st.sampled_from(
        list("ابكلموسدرهن فق،.x1َّـةأٱ"))
    texts = st.lists(st.text(alphabet, max_size=40), min_size=1, max_size=6)

    @hyp.given(texts)
    @hyp.settings(max_examples=25, deadline=None)
    def prop(docs):
        chars, _, _ = tn.coalesce_docs(docs)
        got_w, got_s = _run_tile(_pad(chars))
        want_w, want_s = _expected(docs)
        np.testing.assert_array_equal(got_w, want_w)
        np.testing.assert_array_equal(got_s, want_s)

    prop()


# ---------------------------------------------------------------------------
# named segmentation properties
# ---------------------------------------------------------------------------
def test_byte_spans_round_trip():
    from repro.launch.serve import build_documents

    docs = build_documents(4, 40) + ["ٱلرَّحْمَٰنِ الرَّحِيمِ", "x قلمٌ y"]
    chars, _, _ = tn.coalesce_docs(docs)
    raw = "\0".join(docs).encode("utf-8")
    got_w, got_s = _run_tile(_pad(chars))
    want_w, want_s = _expected(docs)
    np.testing.assert_array_equal(got_w, want_w)
    np.testing.assert_array_equal(got_s, want_s)
    prev = 0
    for row, (b0, b1) in zip(got_w, got_s):
        # spans are increasing, non-overlapping, valid utf-8 slices...
        assert prev <= b0 < b1 <= len(raw)
        surface = raw[b0:b1].decode("utf-8")
        prev = b1
        # ...and re-analysing the surface alone reproduces the word row:
        # the span covers exactly the raw run that produced the row
        again, _ = tn.analyze_text_py(surface)
        assert again.shape[0] == 1
        np.testing.assert_array_equal(again[0], row)


def test_words_longer_than_16_truncate_identically():
    long_words = ["ب" * n for n in (16, 17, 20, 25, 31)]
    # marks inflate the raw window past MAX_RAW=32 without adding letters
    long_words.append("كَ" * 20)          # 40 raw cps, 20 letters
    long_words.append("د" + "ّ" * 40 + "رس")
    doc = " ".join(long_words)
    got_w, got_s = _run_tile(_pad(tn.coalesce_docs([doc])[0]))
    want_w, want_s = _expected([doc])
    np.testing.assert_array_equal(got_w, want_w)
    np.testing.assert_array_equal(got_s, want_s)
    # truncation keeps at most 15 letters and the pad column stays zero
    assert got_w.shape[0] == len(long_words)
    assert (got_w[:, 15] == 0).all()
    assert ((got_w != 0).sum(axis=1) <= 15).all()
    # spans still cover the whole (untruncated) surface run
    raw = doc.encode("utf-8")
    for (b0, b1), w in zip(got_s, long_words):
        assert raw[b0:b1].decode("utf-8") == w


def test_empty_whitespace_and_punctuation_docs():
    docs = ["", "   ", "،؟!", "\n\t ", ".,;:", "ًّ", "قلم"]
    chars, _, _ = tn.coalesce_docs(docs)
    got_w, got_s = _run_tile(_pad(chars))
    want_w, want_s = _expected(docs)
    # a marks-only run is still a token (maximal non-separator run): it
    # keeps its byte span but carries an all-zero letter row, which the
    # stemmer maps to SRC_NONE — plus the one real word
    assert want_w.shape[0] == 2
    assert not want_w[0].any() and want_w[1].any()
    np.testing.assert_array_equal(got_w, want_w)
    np.testing.assert_array_equal(got_s, want_s)
    # an all-separator tile segments to zero words
    chars2, _, _ = tn.coalesce_docs(["", " ،؟ ", "  .. "])
    w2, s2 = _run_tile(_pad(chars2))
    assert w2.shape[0] == 0 and s2.shape[0] == 0


def test_segment_geometry_rejects_empty_tile():
    with pytest.raises(ValueError, match="non-empty"):
        tn.segment_geometry(np.zeros(0, np.int32))


def test_block_w_invariance_and_alignment_guard():
    docs = ["والعلم نور", "كتبها في مدرسة"]
    tile = _pad(tn.coalesce_docs(docs)[0])
    w64, s64 = _run_tile(tile, block_w=64)
    w128, s128 = _run_tile(tile, block_w=128)
    np.testing.assert_array_equal(w64, w128)
    np.testing.assert_array_equal(s64, s128)
    geo = tn.segment_geometry(tile, block_w=128)
    with pytest.raises(ValueError, match="block_w"):
        tf.text_frontend_pallas(tile, geo.starts, geo.lens,
                                block_w=96, interpret=True)


# ---------------------------------------------------------------------------
# fused chain: bytes -> roots with no host round-trip
# ---------------------------------------------------------------------------
def test_ops_text_to_words_matches_host_and_counts_one_dispatch():
    from repro.launch.serve import build_documents

    docs = build_documents(3, 32, seed=5)
    tile = _pad(tn.coalesce_docs(docs)[0])
    ops.reset_dispatch_count()
    words, spans, n_words = ops.text_to_words(tile)
    assert ops.dispatch_count() == 1
    n = int(n_words)
    want_w, want_s = _expected(docs)
    assert n == want_w.shape[0]
    np.testing.assert_array_equal(np.asarray(words)[:n], want_w)
    np.testing.assert_array_equal(np.asarray(spans)[:n], want_s)
    assert not np.asarray(words)[n:].any()


@pytest.mark.parametrize("residency", ["resident", "streamed"])
def test_extract_roots_text_bit_identical(residency):
    import jax.numpy as jnp

    from repro.launch.serve import build_documents

    d = corpus.build_dictionary(n_tri=300, n_quad=40, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    if residency == "streamed":
        arrays = corpus.grow_root_arrays(arrays, 1 << 14, seed=3)
    docs = build_documents(3, 24, seed=7)
    tile = _pad(tn.coalesce_docs(docs)[0])
    roots, sources, spans, n_words = ops.extract_roots_text(
        tile, arrays, residency=residency)
    n = int(n_words)
    want_w, want_s = _expected(docs)
    assert n == want_w.shape[0]
    np.testing.assert_array_equal(np.asarray(spans)[:n], want_s)
    want_r, want_src = stemmer.stem_batch(jnp.asarray(want_w), arrays)
    np.testing.assert_array_equal(np.asarray(roots)[:n],
                                  np.asarray(want_r))
    np.testing.assert_array_equal(np.asarray(sources)[:n],
                                  np.asarray(want_src))
