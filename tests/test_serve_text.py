"""TextAnalysisWorkload: raw documents through the unchanged Engine
machinery, bit-identical to the host normalise -> segment -> stem_batch
pipeline across every front end, resident/streamed dictionaries,
megabatch on/off, the persistent descriptor-ring kernel, and a hot swap
landing mid-stream. Multi-device (data_devices=4) text coverage lives
in test_serve_sharded.py under forced host devices."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, stemmer
from repro.core import textnorm as tn
from repro.serve import (DictStore, Engine, StemmerWorkload,
                         TextAnalysisWorkload, TextRequest, Workload)


@pytest.fixture(scope="module")
def arrays():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    return stemmer.RootDictArrays.from_rootdict(d)


@pytest.fixture(scope="module")
def docs():
    from repro.launch.serve import build_documents

    return build_documents(6, 32, seed=2)


def _oracle(doc_batch, use):
    """Host pipeline for one request's documents."""
    words, spans, ids = [], [], []
    for i, d in enumerate(doc_batch):
        w, s = tn.analyze_text_py(d)
        words.append(w)
        spans.append(s)
        ids.append(np.full(w.shape[0], i, np.int32))
    w = np.concatenate(words) if words else np.zeros((0, 16), np.int32)
    r, src = stemmer.stem_batch(jnp.asarray(w), use)
    return (w, np.concatenate(spans) if spans else np.zeros((0, 2)),
            np.concatenate(ids) if ids else np.zeros(0, np.int32),
            np.asarray(r), np.asarray(src))


def _check(req, doc_batch, use):
    assert req.done
    w, s, ids, r, src = _oracle(doc_batch, use)
    assert req.n_words == w.shape[0]
    np.testing.assert_array_equal(req.words, w)
    np.testing.assert_array_equal(req.spans, s)
    np.testing.assert_array_equal(req.doc_ids, ids)
    np.testing.assert_array_equal(req.roots, r)
    np.testing.assert_array_equal(req.sources, src)
    assert req.n_bytes == sum(len(d.encode("utf-8")) for d in doc_batch)


def _requests(docs):
    # multi-doc, single-doc list, bare string, and a batch with an empty
    # + punctuation-only doc in the middle
    return [docs[:3], [docs[3]], docs[4], [docs[5], "", "،؟ !", docs[0]]]


def _serve(workload, payloads):
    eng = Engine(workload)
    rids = [eng.submit(p) for p in payloads]
    rep = eng.run_until_drained()
    assert rep.drained
    return eng, rids


@pytest.mark.parametrize("frontend", ["kernel", "reference", "host"])
def test_text_serve_parity_all_frontends(arrays, docs, frontend):
    store = DictStore(arrays)
    eng, rids = _serve(
        TextAnalysisWorkload(store, block_b=32, char_block=256,
                             frontend=frontend),
        _requests(docs))
    for rid, payload in zip(rids, _requests(docs)):
        batch = [payload] if isinstance(payload, str) else list(payload)
        _check(eng.result(rid), batch, arrays)


@pytest.mark.parametrize("residency", ["resident", "streamed"])
@pytest.mark.parametrize("megabatch_tiles", [1, 2])
def test_text_serve_residency_x_megabatch(arrays, docs, residency,
                                          megabatch_tiles):
    use = (corpus.grow_root_arrays(arrays, 1 << 14, seed=3)
           if residency == "streamed" else arrays)
    store = DictStore(use, residency=residency)
    eng, rids = _serve(
        TextAnalysisWorkload(store, block_b=32, char_block=256,
                             megabatch_tiles=megabatch_tiles),
        _requests(docs))
    for rid, payload in zip(rids, _requests(docs)):
        batch = [payload] if isinstance(payload, str) else list(payload)
        _check(eng.result(rid), batch, use)


def test_text_serve_persistent(arrays, docs):
    store = DictStore(arrays, residency="resident")
    eng, rids = _serve(
        TextAnalysisWorkload(store, block_b=32, char_block=256,
                             persistent=True, megabatch_tiles=2),
        [docs[:2], docs[2:4]])
    for rid, payload in zip(rids, [docs[:2], docs[2:4]]):
        _check(eng.result(rid), list(payload), arrays)


def test_text_hot_swap_mid_stream(arrays, docs):
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    store = DictStore(arrays)
    eng = Engine(TextAnalysisWorkload(store, block_b=16, char_block=256,
                                      max_inflight=2))
    rids = [eng.submit([d]) for d in docs]
    for _ in range(2):
        eng.step()
    store.publish(grown)
    rep = eng.run_until_drained()
    assert rep.drained
    versions = np.concatenate([eng.result(r).dict_versions for r in rids])
    assert set(versions.tolist()) == {0, 1}   # the swap landed mid-stream
    for rid, d in zip(rids, docs):
        req = eng.result(rid)
        w, s = tn.analyze_text_py(d)
        np.testing.assert_array_equal(req.words, w)
        np.testing.assert_array_equal(req.spans, s)
        # every word's roots must match the dictionary version that
        # actually served it
        for use, ver in ((arrays, 0), (grown, 1)):
            sel = req.dict_versions == ver
            if not sel.any():
                continue
            r, src = stemmer.stem_batch(jnp.asarray(w[sel]), use)
            np.testing.assert_array_equal(req.roots[sel], np.asarray(r))
            np.testing.assert_array_equal(req.sources[sel], np.asarray(src))


def test_text_analyses_scatter_per_document(arrays, docs):
    store = DictStore(arrays)
    batch = [docs[0], "", docs[1]]
    eng, rids = _serve(TextAnalysisWorkload(store, block_b=32,
                                            char_block=256), [batch])
    req = eng.result(rids[0])
    per_doc = req.analyses()
    assert len(per_doc) == 3 and per_doc[1] == []
    for i, d in enumerate(batch):
        w, s = tn.analyze_text_py(d)
        assert len(per_doc[i]) == w.shape[0]
        r, _ = stemmer.stem_batch(jnp.asarray(w), arrays)
        from repro.core import alphabet as ab

        for (root, _src, span), want_r, want_s in zip(per_doc[i],
                                                      np.asarray(r), s):
            assert root == ab.decode_word(want_r)
            assert span == (int(want_s[0]), int(want_s[1]))


def test_text_char_bucketing_bounds_tiles(arrays):
    w = TextAnalysisWorkload(DictStore(arrays), char_block=256)
    assert w._char_bucket(1) == 256
    assert w._char_bucket(256) == 256
    assert w._char_bucket(257) == 512
    assert w._char_bucket(5000) == 8192


def test_text_workload_satisfies_protocol(arrays):
    w = TextAnalysisWorkload(DictStore(arrays))
    assert isinstance(w, (Workload, StemmerWorkload))
    assert isinstance(w.make_request(0, "قلم"), TextRequest)


def test_text_validation_errors(arrays):
    store = DictStore(arrays)
    with pytest.raises(ValueError, match="frontend"):
        TextAnalysisWorkload(store, frontend="gpu")
    with pytest.raises(ValueError, match="char_block"):
        TextAnalysisWorkload(store, char_block=64)
    w = TextAnalysisWorkload(store)
    with pytest.raises(ValueError, match="str documents"):
        w.make_request(0, [b"bytes not str"])
    with pytest.raises(ValueError, match="unknown text request options"):
        w.make_request(0, ["قلم"], max_new=4)


def test_text_empty_request_completes(arrays):
    store = DictStore(arrays)
    eng, rids = _serve(TextAnalysisWorkload(store, block_b=16), [[], ""])
    for rid in rids:
        req = eng.result(rid)
        assert req.done and req.n_words == 0
        assert req.analyses() == ([] if req.docs == [] else [[]])
