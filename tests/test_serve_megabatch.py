"""Grid-over-queue megabatch + persistent-kernel serving tests.

Megabatch coalescing (one launch retires many queued tiles), bit-exact
parity against the synchronous per-tile tick — including ragged final
megabatches and a DictStore hot swap landing while a megabatch is in
flight — the persistent descriptor-ring kernel's parity and completion
flags, the scalar-prefetch visit-table chunking that keeps megabatch
SMEM tables within budget, and the dispatch accounting
(ops.dispatch_count / stem_fused.planned_launches) that proves one
``pallas_call`` retires >= 4 queue tiles. Sharded-megabatch coverage
lives in test_serve_sharded.py under forced host devices.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, stemmer
from repro.kernels import ops
from repro.kernels import stem_fused as sf
from repro.serve import DictStore, Engine, StemmerWorkload

MATCHES = ("bank", "bsearch")


@pytest.fixture(scope="module")
def dicts():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    return stemmer.RootDictArrays.from_rootdict(d)


@pytest.fixture(scope="module")
def enc():
    words, _, _ = corpus.build_corpus(n_words=600, seed=1)
    return corpus.encode_corpus(words)


def _serve(store, enc, sizes, *, block_b=32, megabatch_tiles=1,
           persistent=False, max_inflight=2, steps_before_swap=None,
           swap_to=None):
    eng = Engine(StemmerWorkload(store, block_b=block_b,
                                 megabatch_tiles=megabatch_tiles,
                                 persistent=persistent,
                                 max_inflight=max_inflight))
    off, rids = 0, []
    for n in sizes:
        rids.append(eng.submit(enc[off:off + n]))
        off += n
    if steps_before_swap is not None:
        for _ in range(steps_before_swap):
            eng.step()
        store.publish(swap_to)
    rep = eng.run_until_drained()
    assert rep.drained
    return eng, rids


def _gather(eng, rids):
    reqs = [eng.result(r) for r in rids]
    assert all(r.done for r in reqs)
    return (np.concatenate([r.roots for r in reqs]),
            np.concatenate([r.sources for r in reqs]),
            np.concatenate([r.dict_versions for r in reqs]))


# ---------------------------------------------------------------------------
# persistent kernel: descriptor-ring parity + completion flags
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("infix", [True, False])
def test_persistent_resident_parity(dicts, enc, infix, match):
    ref_r, ref_s = stemmer.stem_batch(jnp.asarray(enc), dicts, infix=infix)
    r, s, fl = ops.extract_roots_persistent(
        jnp.asarray(enc), dicts, infix=infix, match=match, block_b=128,
        residency="resident", version_slot=5, interpret=True)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(ref_r))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    # 600 words / block_b=128 -> 5 descriptors, each flagged 1 + slot
    assert np.asarray(fl).shape == (5,)
    assert (np.asarray(fl) == 6).all()


@pytest.mark.parametrize("match", MATCHES)
def test_persistent_streamed_parity(dicts, enc, match):
    ref_r, ref_s = stemmer.stem_batch(jnp.asarray(enc), dicts)
    r, s, fl = ops.extract_roots_persistent(
        jnp.asarray(enc), dicts, match=match, block_b=128,
        residency="streamed", dict_block_r=2, version_slot=0,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(ref_r))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    assert (np.asarray(fl) == 1).all()


def test_persistent_ragged_batch(dicts, enc):
    """A batch that is not a multiple of block_b pads its final
    descriptor; the padded words never leak into the sliced output."""
    ref_r, ref_s = stemmer.stem_batch(jnp.asarray(enc[:77]), dicts)
    r, s, fl = ops.extract_roots_persistent(
        jnp.asarray(enc[:77]), dicts, block_b=32, residency="streamed",
        dict_block_r=2, interpret=True)
    assert r.shape == (77, 4) and s.shape == (77,)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(ref_r))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ref_s))
    assert np.asarray(fl).shape == (3,)  # ceil(77 / 32) descriptors


def test_persistent_empty_batch(dicts):
    r, s, fl = ops.extract_roots_persistent(
        jnp.zeros((0, 16), jnp.int32), dicts, interpret=True)
    assert r.shape == (0, 4) and s.shape == (0,) and fl.shape == (0,)


# ---------------------------------------------------------------------------
# visit-table chunking: megabatch SMEM tables stay within budget
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("persistent", [False, True])
def test_visit_budget_chunking_parity(dicts, enc, persistent):
    """A visit budget smaller than the megabatch's table forces the
    streamed path to chunk along the batch axis — output stays
    bit-identical and planned_launches mirrors the actual chunk count."""
    ref_r, ref_s = stemmer.stem_batch(jnp.asarray(enc), dicts)
    n_tiles = sf.dict_tile_count(dicts, 2)
    budget = 2 * n_tiles  # two batch tiles of table per chunk
    kw = dict(block_b=64, residency="streamed", dict_block_r=2,
              visit_budget=budget, interpret=True)
    fn = ops.extract_roots_persistent if persistent else ops.extract_roots_fused
    ops.reset_dispatch_count()
    out = fn(jnp.asarray(enc), dicts, **kw)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref_r))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref_s))
    want = sf.planned_launches(len(enc), dicts, block_b=64,
                               residency="streamed", dict_block_r=2,
                               persistent=persistent, visit_budget=budget)
    # 600 words / 64 = 10 batch tiles, 2 per chunk -> 5 pallas_calls
    assert want == 5
    assert ops.dispatch_count() == want
    if persistent:
        assert np.asarray(out[2]).shape == (10,)
        assert (np.asarray(out[2]) == 1).all()


def test_planned_launches_counts(dicts):
    assert sf.planned_launches(0, dicts) == 0
    assert sf.planned_launches(512, dicts, residency="resident") == 1
    # default budget comfortably fits this dictionary: one launch
    assert sf.planned_launches(512, dicts, block_b=64,
                               residency="streamed", dict_block_r=2) == 1
    # budget below one batch tile's table still launches (1 tile/chunk)
    n_tiles = sf.dict_tile_count(dicts, 2)
    assert sf.planned_launches(512, dicts, block_b=64,
                               residency="streamed", dict_block_r=2,
                               visit_budget=n_tiles - 1) == 8


# ---------------------------------------------------------------------------
# megabatch serving: one dispatch retires many queued tiles
# ---------------------------------------------------------------------------
def test_megabatch_single_launch_retires_four_tiles(dicts, enc):
    """The acceptance criterion: ONE pallas_call dispatch retires >= 4
    queued tiles, bit-identical to the per-tile path."""
    sizes = (37, 64, 5, 22)  # 128 words = 4 tiles of 32
    store = DictStore(dicts)
    ops.reset_dispatch_count()
    eng, rids = _serve(store, enc, sizes, block_b=32, megabatch_tiles=4,
                       max_inflight=1)
    assert eng.workload.ticks_launched == 1
    assert ops.dispatch_count() == 1
    got_r, got_s, _ = _gather(eng, rids)

    store2 = DictStore(dicts)
    eng2, rids2 = _serve(store2, enc, sizes, block_b=32, max_inflight=1)
    assert eng2.workload.ticks_launched == 4  # the per-tile baseline
    ref_r, ref_s, _ = _gather(eng2, rids2)
    np.testing.assert_array_equal(got_r, ref_r)
    np.testing.assert_array_equal(got_s, ref_s)


@pytest.mark.parametrize("megabatch_tiles,persistent",
                         [(4, False), (8, False), (1, True), (4, True)])
def test_megabatch_parity_vs_sync_tick(dicts, enc, megabatch_tiles,
                                       persistent):
    """Bit-identity against the max_inflight=1 synchronous per-tile tick,
    including the ragged final megabatch (sizes don't fill the last
    launch)."""
    sizes = (37, 120, 5, 50, 99)  # 311 words: ragged at every tile size
    ref_eng, ref_rids = _serve(DictStore(dicts), enc, sizes, max_inflight=1)
    ref = _gather(ref_eng, ref_rids)
    eng, rids = _serve(DictStore(dicts), enc, sizes,
                       megabatch_tiles=megabatch_tiles,
                       persistent=persistent)
    got = _gather(eng, rids)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(g, r)
    if megabatch_tiles > 1:
        assert eng.workload.ticks_launched < ref_eng.workload.ticks_launched


@pytest.mark.parametrize("persistent", [False, True])
def test_megabatch_parity_across_midflight_swap(dicts, enc, persistent):
    """A DictStore publish landing while a megabatch is in flight never
    relabels (or re-serves) its words: each word records the version its
    launch pinned, and words served after the swap match the new dict."""
    d2 = corpus.build_dictionary(n_tri=500, n_quad=80, seed=5)
    arrays2 = stemmer.RootDictArrays.from_rootdict(d2)
    sizes = (100, 100, 100)
    store = DictStore(dicts)
    eng, rids = _serve(store, enc, sizes, megabatch_tiles=2,
                       persistent=persistent, max_inflight=2,
                       steps_before_swap=1, swap_to=arrays2)
    got_r, got_s, got_v = _gather(eng, rids)
    assert store.version == 1
    assert got_v.min() == 0 and got_v.max() == 1  # swap landed mid-stream
    # every word must match the dictionary version that served it
    for v, arrays in ((0, dicts), (1, arrays2)):
        idx = np.nonzero(got_v == v)[0]
        want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:300][idx]),
                                            arrays)
        np.testing.assert_array_equal(got_r[idx], np.asarray(want_r))
        np.testing.assert_array_equal(got_s[idx], np.asarray(want_s))


def test_persistent_serve_flags_checked(dicts, enc):
    """The persistent retire verifies completion flags against the
    pinned version — a launch whose flags disagree is a hard error."""
    store = DictStore(dicts)
    eng = Engine(StemmerWorkload(store, block_b=32, persistent=True,
                                 max_inflight=1))
    eng.submit(enc[:64])
    eng.run_until_drained()  # healthy path: no raise, versions stamped
    req = eng.result(0)
    assert (req.dict_versions == 0).all()


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_megabatch_tiles_validation(dicts):
    with pytest.raises(ValueError, match="megabatch_tiles"):
        StemmerWorkload(DictStore(dicts), megabatch_tiles=0)


def test_persistent_sharded_rejected(dicts):
    with pytest.raises(ValueError, match="persistent"):
        StemmerWorkload(DictStore(dicts), persistent=True, data_devices=2)
