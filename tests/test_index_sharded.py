"""Sharded corpus indexing (4 forced host devices via subprocess — the
main pytest session must keep the default single device).

The acceptance bar from the corpus-indexing tentpole: a >= 1M-word
synthetic corpus indexed over the ``("data",)`` mesh must be
bit-identical to the host numpy reference build — same counts, same
postings, same within-root order — with the per-shard partial indexes
merged on device (the stacked tile histograms + global cumsum inside
``ops.build_root_index``). Also pins the sharded path's
``dispatch_count`` accounting at n_dev x (stemmer + postings) launches.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro import index as ix
    from repro.core import corpus, stemmer
    from repro.kernels import ops
    from repro.kernels import stem_fused as sf
    from repro.launch import mesh as mesh_mod

    assert len(jax.devices()) == 4
    mesh = mesh_mod.make_data_mesh(4)
    d = corpus.build_dictionary(n_tri=2000, n_quad=200, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    vocab = ix.build_vocab(arrays)
    table = corpus.build_token_table()

    # --- small sharded chunk: parity vs single-device AND vs host -----
    ch = next(corpus.stream_corpus_words(5000, seed=7, chunk_words=5000,
                                         words_per_doc=250, table=table))
    got = ops.build_root_index(ch.words, arrays, vocab, ch.doc_ids,
                               ch.positions, mesh=mesh, block_b=256,
                               block_w=256)
    one = ops.build_root_index(ch.words, arrays, vocab, ch.doc_ids,
                               ch.positions, block_b=256, block_w=256)
    n = int(got[3])
    assert n == int(one[3])
    for g, o in zip(got[:3], one[:3]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(o))
    ids = ix.host_root_ids(ch.words, arrays, vocab)
    wc, wd, wp = ix.host_index(ids, ch.doc_ids.astype(np.int32),
                               ch.positions, len(vocab))
    np.testing.assert_array_equal(np.asarray(got[0]), wc)
    np.testing.assert_array_equal(np.asarray(got[1])[:n], wd)
    np.testing.assert_array_equal(np.asarray(got[2])[:n], wp)
    print("SHARDED_CHUNK_PARITY_OK")

    # --- dispatch accounting: n_dev x (stemmer + postings) ------------
    ops.reset_dispatch_count()
    ops.build_root_index(ch.words, arrays, vocab, ch.doc_ids,
                         ch.positions, mesh=mesh, block_b=256,
                         block_w=256)
    per_dev = -(-ch.words.shape[0] // 4)
    want = 4 * (sf.planned_launches(per_dev, arrays, block_b=256) + 1)
    assert ops.dispatch_count() == want, (ops.dispatch_count(), want)
    print("SHARDED_DISPATCH_COUNT_OK")

    # --- the acceptance scale: 1M words over the mesh vs host ---------
    n_words = 1 << 20

    def stream():
        return corpus.stream_corpus_words(n_words, seed=0,
                                          chunk_words=1 << 17,
                                          words_per_doc=512, table=table)

    idx = ix.build_corpus_index(stream(), arrays, mesh=mesh,
                                block_b=2048, block_w=2048)
    parts = []
    for ch in stream():
        ids = ix.host_root_ids(ch.words, arrays, vocab)
        parts.append(ix.IndexPartial(
            *ix.host_index(ids, ch.doc_ids.astype(np.int32),
                           ch.positions, len(vocab))))
    want = ix.merge_partials(parts, vocab)
    np.testing.assert_array_equal(idx.counts, want.counts)
    np.testing.assert_array_equal(idx.docs, want.docs)
    np.testing.assert_array_equal(idx.positions, want.positions)
    assert idx.n_postings > n_words // 2
    print("SHARDED_MILLION_WORD_OK", idx.n_postings)
""")


def test_sharded_index_four_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    for marker in ("SHARDED_CHUNK_PARITY_OK", "SHARDED_DISPATCH_COUNT_OK",
                   "SHARDED_MILLION_WORD_OK"):
        assert marker in proc.stdout, proc.stderr[-3000:]
