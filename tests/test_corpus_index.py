"""Corpus indexing: streams, the postings kernel, the chunked driver.

Parity discipline matches the rest of the suite: the device index build
(megakernel -> postings reduction -> scatter) must be bit-identical to
the host numpy reference (stem_batch ids + stable argsort) — same
per-root counts, same postings, same within-root (global word) order —
including at the 1M-word acceptance scale, and a checkpoint/resume
split must reproduce the same index.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro import index as ix
from repro.core import corpus, stemmer
from repro.core import textnorm as tn
from repro.kernels import ops
from repro.kernels import postings as pk


@pytest.fixture(scope="module")
def table():
    return corpus.build_token_table(forms_per_root=6)


@pytest.fixture(scope="module")
def dict_and_vocab():
    d = corpus.build_dictionary(n_tri=300, n_quad=40, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    return arrays, ix.build_vocab(arrays)


def _host(arrays, vocab, chunks):
    words = np.concatenate([c.words for c in chunks])
    docs = np.concatenate([c.doc_ids for c in chunks]).astype(np.int32)
    poss = np.concatenate([c.positions for c in chunks])
    ids = ix.host_root_ids(words, arrays, vocab)
    return ix.host_index(ids, docs, poss, len(vocab))


def _assert_index_equal(idx, want):
    want_counts, want_docs, want_poss = want
    np.testing.assert_array_equal(idx.counts, want_counts)
    np.testing.assert_array_equal(idx.docs, want_docs)
    np.testing.assert_array_equal(idx.positions, want_poss)


# ---------------------------------------------------------------------------
# corpus streams
# ---------------------------------------------------------------------------
def test_stream_determinism(table):
    a = list(corpus.stream_corpus_words(5000, seed=9, chunk_words=2048,
                                        table=table))
    b = list(corpus.stream_corpus_words(5000, seed=9, chunk_words=2048,
                                        table=table))
    assert len(a) == len(b) == 3
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.words, y.words)
        np.testing.assert_array_equal(x.doc_ids, y.doc_ids)
        np.testing.assert_array_equal(x.positions, y.positions)
    c = next(corpus.stream_corpus_words(5000, seed=10, chunk_words=2048,
                                        table=table))
    assert not np.array_equal(a[0].words, c.words)


def test_stream_chunks_are_seeded_independently(table):
    """Chunk c depends on (seed, c) alone — a resumed build can skip
    ahead without replaying earlier chunks' rng draws."""
    full = list(corpus.stream_corpus_words(6000, seed=4, chunk_words=2048,
                                           table=table))
    tail = list(corpus.stream_corpus_words(6000, seed=4, chunk_words=2048,
                                           table=table))[2:]
    np.testing.assert_array_equal(full[2].words, tail[0].words)
    # doc ids / positions are functions of the global word index
    ch = full[1]
    gwi = ch.start_word + np.arange(ch.n_words)
    np.testing.assert_array_equal(ch.doc_ids, gwi // 1000)
    np.testing.assert_array_equal(ch.positions, gwi % 1000)


def test_stream_docs_roundtrip_frontend(table):
    """Generated text must round-trip the PR 7 normalisation tables: the
    python front end on the rendered documents reproduces exactly the
    word rows the fast path emits."""
    wchunks = list(corpus.stream_corpus_words(600, seed=5, chunk_words=300,
                                              words_per_doc=50, table=table))
    dchunks = list(corpus.stream_corpus_docs(600, seed=5, chunk_words=300,
                                             words_per_doc=50, table=table))
    for wc, (doc0, docs) in zip(wchunks, dchunks):
        assert doc0 == wc.doc_ids[0]
        got = np.concatenate([tn.analyze_text_py(doc)[0] for doc in docs])
        np.testing.assert_array_equal(got, wc.words)


def test_stream_docs_rejects_straddling_chunks(table):
    with pytest.raises(ValueError, match="multiple"):
        next(corpus.stream_corpus_docs(600, chunk_words=300,
                                       words_per_doc=77, table=table))


# ---------------------------------------------------------------------------
# the postings reduction kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block_w", [128, 256])
def test_postings_kernel_vs_numpy(block_w):
    rng = np.random.default_rng(0)
    n_roots, w = 53, 1000           # ragged: pads up with drop ids
    ids = rng.integers(0, n_roots + 1, size=w).astype(np.int32)
    docs = rng.integers(0, 40, size=w).astype(np.int32)
    poss = np.arange(w, dtype=np.int32)
    hist, rank = pk.postings_pallas(jnp.asarray(ids), n_roots=n_roots,
                                    block_w=block_w, interpret=True)
    counts, d_out, p_out, n_post = map(np.asarray, pk.finish_postings(
        hist, rank, jnp.asarray(ids), jnp.asarray(docs), jnp.asarray(poss),
        n_roots=n_roots, block_w=block_w))
    valid = ids < n_roots
    order = np.argsort(ids[valid], kind="stable")
    np.testing.assert_array_equal(counts,
                                  np.bincount(ids[valid],
                                              minlength=n_roots))
    assert int(n_post) == int(valid.sum())
    np.testing.assert_array_equal(d_out[:n_post], docs[valid][order])
    np.testing.assert_array_equal(p_out[:n_post], poss[valid][order])
    # per-tile histograms must partition the padded words
    assert int(np.asarray(hist).sum()) == -(-w // block_w) * block_w


def test_postings_kernel_all_dropped():
    ids = jnp.full((200,), 7, jnp.int32)       # everything in the drop bucket
    hist, rank = pk.postings_pallas(ids, n_roots=7, block_w=128,
                                    interpret=True)
    counts, _, _, n_post = pk.finish_postings(
        hist, rank, ids, jnp.zeros(200, jnp.int32),
        jnp.zeros(200, jnp.int32), n_roots=7, block_w=128)
    assert int(n_post) == 0
    assert int(jnp.sum(counts)) == 0


def test_postings_kernel_validation():
    ids = jnp.zeros((8,), jnp.int32)
    with pytest.raises(ValueError, match="power of two"):
        pk.postings_pallas(ids, n_roots=4, block_w=96, interpret=True)
    with pytest.raises(ValueError, match="overflow"):
        pk.postings_pallas(ids, n_roots=1 << 22, block_w=1024,
                           interpret=True)


# ---------------------------------------------------------------------------
# ops.build_root_index: words path and text path
# ---------------------------------------------------------------------------
def test_build_root_index_matches_host(dict_and_vocab, table):
    arrays, vocab = dict_and_vocab
    chunks = list(corpus.stream_corpus_words(3000, seed=2, chunk_words=3000,
                                             words_per_doc=200, table=table))
    (ch,) = chunks
    counts, docs, poss, n_post = ops.build_root_index(
        ch.words, arrays, vocab, ch.doc_ids, ch.positions, block_b=256,
        block_w=256)
    n_post = int(n_post)
    want_counts, want_docs, want_poss = _host(arrays, vocab, chunks)
    np.testing.assert_array_equal(np.asarray(counts), want_counts)
    np.testing.assert_array_equal(np.asarray(docs)[:n_post], want_docs)
    np.testing.assert_array_equal(np.asarray(poss)[:n_post], want_poss)


def test_text_path_matches_words_path(dict_and_vocab, table):
    arrays, vocab = dict_and_vocab
    n, wpd = 1200, 60
    wc = next(corpus.stream_corpus_words(n, seed=6, chunk_words=n,
                                         words_per_doc=wpd, table=table))
    doc0, docs = next(corpus.stream_corpus_docs(n, seed=6, chunk_words=n,
                                                words_per_doc=wpd,
                                                table=table))
    chars, _, byte_off = tn.coalesce_docs(docs)
    got = ops.build_root_index_text(chars, arrays, vocab, byte_off,
                                    doc0=doc0, block_b=256, block_w=512)
    want = ops.build_root_index(wc.words, arrays, vocab, wc.doc_ids,
                                wc.positions, block_b=256, block_w=512)
    n_post = int(want[3])
    assert int(got[3]) == n_post
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1])[:n_post],
                                  np.asarray(want[1])[:n_post])
    np.testing.assert_array_equal(np.asarray(got[2])[:n_post],
                                  np.asarray(want[2])[:n_post])


# ---------------------------------------------------------------------------
# the chunked driver: checkpoint / resume / DictStore pinning
# ---------------------------------------------------------------------------
def _stream(table, n=12000, chunk=4096, seed=3):
    return corpus.stream_corpus_words(n, seed=seed, chunk_words=chunk,
                                      words_per_doc=500, table=table)


def test_builder_parity_and_merge(dict_and_vocab, table):
    arrays, vocab = dict_and_vocab
    idx = ix.build_corpus_index(_stream(table), arrays, block_b=512,
                                block_w=512)
    _assert_index_equal(idx, _host(arrays, vocab, list(_stream(table))))
    np.testing.assert_array_equal(idx.offsets,
                                  np.cumsum(idx.counts) - idx.counts)
    assert idx.n_postings == int(idx.counts.sum())


def test_checkpoint_resume_bit_identical(dict_and_vocab, table, tmp_path):
    arrays, _ = dict_and_vocab
    full = ix.build_corpus_index(_stream(table), arrays, block_b=512,
                                 block_w=512)
    # complete 2 of 3 chunks, "crash", then resume over the full stream
    ckpt = str(tmp_path / "ckpt")
    ix.build_corpus_index(itertools.islice(_stream(table), 2), arrays,
                          checkpoint_dir=ckpt, block_b=512, block_w=512)
    resumed = ix.build_corpus_index(_stream(table), arrays,
                                    checkpoint_dir=ckpt, resume=True,
                                    block_b=512, block_w=512)
    np.testing.assert_array_equal(resumed.counts, full.counts)
    np.testing.assert_array_equal(resumed.docs, full.docs)
    np.testing.assert_array_equal(resumed.positions, full.positions)
    assert resumed.dict_versions == (0, 0, 0)


def test_checkpoint_manifest_records_content_hashes(dict_and_vocab, table,
                                                    tmp_path):
    """Every checkpointed chunk carries a content hash in the manifest
    (schema 2), and the hash matches the partial actually on disk —
    the integrity contract torn-checkpoint recovery relies on (the
    fault-driven recovery paths live in test_serve_faults.py)."""
    import json
    import os

    from repro.index import builder as bld

    arrays, _ = dict_and_vocab
    ckpt = tmp_path / "ckpt"
    ix.build_corpus_index(_stream(table), arrays, checkpoint_dir=str(ckpt),
                          block_b=512, block_w=512)
    man = json.loads((ckpt / "manifest.json").read_text())
    assert man["schema"] == bld.MANIFEST_SCHEMA == 2
    assert len(man["chunks"]) == 3
    for rec in man["chunks"]:
        path = os.path.join(str(ckpt), f"chunk_{rec['i']:06d}.npz")
        assert bld._file_sha(path) == rec["sha"]


def test_resume_rejects_divergent_stream(dict_and_vocab, table, tmp_path):
    arrays, _ = dict_and_vocab
    ckpt = str(tmp_path / "ckpt")
    ix.build_corpus_index(itertools.islice(_stream(table), 1), arrays,
                          checkpoint_dir=ckpt, block_b=512, block_w=512)
    other = corpus.stream_corpus_words(12000, seed=3, chunk_words=2048,
                                       words_per_doc=500, table=table)
    with pytest.raises(ValueError, match="diverges"):
        ix.build_corpus_index(other, arrays, checkpoint_dir=ckpt,
                              resume=True, block_b=512, block_w=512)


def test_resume_rejects_vocab_mismatch(dict_and_vocab, table, tmp_path):
    arrays, _ = dict_and_vocab
    ckpt = str(tmp_path / "ckpt")
    ix.build_corpus_index(itertools.islice(_stream(table), 1), arrays,
                          checkpoint_dir=ckpt, block_b=512, block_w=512)
    grown = corpus.grow_root_arrays(arrays, 4096, seed=1)
    with pytest.raises(ValueError, match="vocabulary"):
        ix.build_corpus_index(_stream(table), grown, checkpoint_dir=ckpt,
                              resume=True, block_b=512, block_w=512)


def test_builder_records_dictstore_versions(dict_and_vocab, table):
    from repro.serve import DictStore

    arrays, _ = dict_and_vocab
    store = DictStore(arrays)
    chunks = list(_stream(table))

    def publishing_stream():
        for i, ch in enumerate(chunks):
            if i == 1:        # a publish lands between chunks 0 and 1
                store.publish(corpus.grow_root_arrays(arrays, 2048, seed=8))
            yield ch

    idx = ix.build_corpus_index(publishing_stream(), store, block_b=512,
                                block_w=512)
    assert idx.dict_versions == (0, 1, 1)
    # chunk 0 stems under v0, later chunks under v1; host mirror per chunk
    vocab = ix.build_vocab(arrays)
    parts = []
    for ch, v in zip(chunks, idx.dict_versions):
        ids = ix.host_root_ids(ch.words, store.get(v).arrays, vocab)
        parts.append(ix.IndexPartial(
            *ix.host_index(ids, ch.doc_ids.astype(np.int32),
                           ch.positions, len(vocab))))
    want = ix.merge_partials(parts, vocab)
    np.testing.assert_array_equal(idx.counts, want.counts)
    np.testing.assert_array_equal(idx.docs, want.docs)
    np.testing.assert_array_equal(idx.positions, want.positions)


# ---------------------------------------------------------------------------
# the acceptance scale: >= 1M words, bit-identical to the host reference
# ---------------------------------------------------------------------------
def test_million_word_index_bit_identity():
    d = corpus.build_dictionary(n_tri=2000, n_quad=200, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    vocab = ix.build_vocab(arrays)
    table = corpus.build_token_table()
    n = 1 << 20                                    # 1,048,576 words

    def stream():
        return corpus.stream_corpus_words(n, seed=0, chunk_words=1 << 17,
                                          words_per_doc=512, table=table)

    idx = ix.build_corpus_index(stream(), arrays, block_b=2048,
                                block_w=2048)
    assert idx.n_postings > n // 2                 # the corpus is indexable
    want_counts = np.zeros(len(vocab), np.int64)
    parts = []
    for ch in stream():
        ids = ix.host_root_ids(ch.words, arrays, vocab)
        parts.append(ix.IndexPartial(
            *ix.host_index(ids, ch.doc_ids.astype(np.int32),
                           ch.positions, len(vocab))))
        want_counts += parts[-1].counts
    want = ix.merge_partials(parts, vocab)
    np.testing.assert_array_equal(idx.counts, want_counts)
    _assert_index_equal(idx, (want.counts, want.docs, want.positions))
