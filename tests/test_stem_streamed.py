"""Streamed-dictionary megakernel tests (DESIGN.md §5.3).

Parity of the streamed Compare path against the resident layout and the
core jnp stemmer across match strategy x infix x dictionary sizes
straddling the old 64K-key VMEM ceiling; the residency="auto" policy;
degenerate inputs; and the residency plumbing through the dist pipeline
stage split and the autotuner.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, pyref, stemmer
from repro.dist import pipeline as dist_pipeline
from repro.kernels import ops
from repro.kernels import stem_fused as sf

MATCHES = ("bank", "bsearch")


@pytest.fixture(scope="module")
def small():
    d = corpus.build_dictionary(n_tri=600, n_quad=80, seed=9)
    return stemmer.RootDictArrays.from_rootdict(d)


@pytest.fixture(scope="module")
def big(small):
    # ~100K keys: straddles MAX_RESIDENT_KEYS (64K) from above
    da = corpus.grow_root_arrays(small, 100_000, seed=2)
    total = sum(int(x.shape[0]) for x in (da.tri, da.quad, da.bi))
    assert total > sf.MAX_RESIDENT_KEYS
    return da


@pytest.fixture(scope="module")
def enc():
    words, _, _ = corpus.build_corpus(n_words=384, seed=13)
    return jnp.asarray(corpus.encode_corpus(words))


# ---------------------------------------------------------------------------
# parity: streamed == resident == core jnp, below and above the ceiling
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("infix", [True, False])
def test_streamed_matches_resident_small_dict(small, enc, infix, match):
    ref = stemmer.stem_batch(enc, small, infix=infix)
    res = ops.extract_roots_fused(enc, small, infix=infix, match=match,
                                  residency="resident", interpret=True)
    stm = ops.extract_roots_fused(enc, small, infix=infix, match=match,
                                  residency="streamed", block_b=128,
                                  dict_block_r=2, interpret=True)
    for got in (res, stm):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("infix", [True, False])
def test_streamed_past_ceiling_matches_core(big, enc, infix, match):
    """Above 64K keys the old path raised; streamed must be bit-identical
    to the core sorted backend."""
    ref = stemmer.stem_batch(enc, big, infix=infix)
    got = ops.extract_roots_fused(enc, big, infix=infix, match=match,
                                  residency="streamed", block_b=128,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


@pytest.mark.parametrize("dict_block_r", [1, 4, 16])
def test_streamed_dict_tile_sweep(small, enc, dict_block_r):
    ref = stemmer.stem_batch(enc, small)
    got = ops.extract_roots_fused(enc, small, residency="streamed",
                                  dict_block_r=dict_block_r, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


def test_256k_dictionary_through_public_api(small):
    """The acceptance bar: extract_roots(backend="fused") with a 256K-key
    dictionary succeeds (the old path raised) and is bit-identical to
    backend="sorted"."""
    da = corpus.grow_root_arrays(small, 262_144, seed=5)
    total = sum(int(x.shape[0]) for x in (da.tri, da.quad, da.bi))
    assert total >= 262_144
    words, _, _ = corpus.build_corpus(n_words=192, seed=17)
    e = jnp.asarray(corpus.encode_corpus(words))
    r1, s1 = stemmer.extract_roots(e, da, backend="fused")   # auto -> streamed
    r2, s2 = stemmer.extract_roots(e, da, backend="sorted")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert (np.asarray(s1) != pyref.SRC_NONE).any()  # real hits occurred


# ---------------------------------------------------------------------------
# residency policy
# ---------------------------------------------------------------------------
def test_auto_residency_policy(small, big):
    assert sf.choose_residency(small, "auto") == "resident"
    assert sf.choose_residency(big, "auto") == "streamed"
    assert sf.choose_residency(big, "streamed") == "streamed"
    with pytest.raises(ValueError, match="residency"):
        sf.choose_residency(small, "vmem")


def test_explicit_resident_past_budget_raises(big, enc):
    with pytest.raises(ValueError, match="VMEM residency"):
        ops.extract_roots_fused(enc, big, residency="resident",
                                interpret=True)


def test_auto_streams_past_budget(big, enc):
    """The old hard ValueError is gone: the default residency serves an
    over-budget dictionary by streaming."""
    ref = stemmer.stem_batch(enc, big)
    got = ops.extract_roots_fused(enc, big, interpret=True)  # residency=auto
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


# ---------------------------------------------------------------------------
# degenerate inputs
# ---------------------------------------------------------------------------
def test_streamed_empty_batch(small):
    root, src = ops.extract_roots_fused(
        jnp.zeros((0, 16), jnp.int32), small, residency="streamed",
        interpret=True)
    assert root.shape == (0, 4) and src.shape == (0,)


@pytest.mark.parametrize("match", MATCHES)
def test_streamed_empty_dict_groups(match, enc):
    """Empty quad/bi groups pack to the [-1] placeholder; the streamed
    sweep must neither match the placeholder nor mis-route groups."""
    d = pyref.RootDict.from_words(
        tri=["كتب", "درس", "لعب", "قول", "علم"], quad=[], bi=[])
    da = stemmer.RootDictArrays.from_rootdict(d)
    assert int(da.quad[0]) == -1 and int(da.bi[0]) == -1
    ref = stemmer.stem_batch(enc, da)
    got = ops.extract_roots_fused(enc, da, match=match, residency="streamed",
                                  dict_block_r=2, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


def test_streamed_all_empty_dicts(enc):
    da = stemmer.RootDictArrays.from_rootdict(pyref.RootDict.from_words())
    root, src = ops.extract_roots_fused(enc, da, residency="streamed",
                                        interpret=True)
    assert (np.asarray(src) == pyref.SRC_NONE).all()
    assert (np.asarray(root) == 0).all()


# ---------------------------------------------------------------------------
# residency through the public layers
# ---------------------------------------------------------------------------
def test_residency_through_stem_pipelined(big, enc):
    r1, s1 = stemmer.stem_pipelined(enc, big, backend="fused",
                                    residency="streamed", microbatch=128)
    r2, s2 = stemmer.stem_batch(enc, big)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_residency_through_dist_stage_fns(big, enc):
    """The 5-stage dist split with streamed Compare == stem_batch. Stage
    fns are plain bundle->bundle functions, so parity needs no mesh."""
    bundle = {
        "words": enc,
        "keys": jnp.zeros((enc.shape[0], 32), jnp.int32),
        "valid": jnp.zeros((enc.shape[0], 32), jnp.int32),
        "root": jnp.zeros((enc.shape[0], 4), jnp.int32),
        "source": jnp.zeros((enc.shape[0],), jnp.int32),
    }
    for fn in dist_pipeline.stemmer_stage_fns(big, residency="streamed",
                                              chunk_keys=4096):
        bundle = fn(bundle)
    ref_root, ref_src = stemmer.stem_batch(enc, big)
    np.testing.assert_array_equal(np.asarray(bundle["root"]),
                                  np.asarray(ref_root))
    np.testing.assert_array_equal(np.asarray(bundle["source"]),
                                  np.asarray(ref_src))


def test_extended_plumbs_through_all_execution_models(small):
    """stem_sequential / stem_pipelined must honour the extended rule pool
    exactly like stem_batch (they used to silently drop it)."""
    words, _, _ = corpus.build_corpus(n_words=48, seed=29)
    e = jnp.asarray(corpus.encode_corpus(words))
    ref = stemmer.stem_batch(e, small, extended=True)
    seq = stemmer.stem_sequential(e, small, extended=True)
    pip = stemmer.stem_pipelined(e, small, extended=True, microbatch=16)
    for got in (seq, pip):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


def test_autotune_covers_residency(small):
    words, _, _ = corpus.build_corpus(n_words=128, seed=3)
    e = jnp.asarray(corpus.encode_corpus(words))
    cfg = ops.autotune_stem_fused(e, small, block_bs=(64,),
                                  matches=("bsearch",),
                                  residencies=("resident", "streamed"),
                                  dict_block_rs=(2, 4), num_bufferss=(1, 2),
                                  skip_indexes=(True, False), iters=1,
                                  interpret=True)
    assert cfg["residency"] in ("resident", "streamed")
    assert cfg["dict_block_r"] >= 1
    assert cfg["num_buffers"] >= 1
    assert isinstance(cfg["skip_index"], bool)
    tuned = set(cfg["timings"])
    # resident rows use placeholder zeros for the streamed-only knobs
    assert (64, "bsearch", "resident", 0, 0, True) in tuned
    for dr in (2, 4):
        for nb in (1, 2):
            for sk in (True, False):
                assert (64, "bsearch", "streamed", dr, nb, sk) in tuned


def test_autotune_no_runnable_config_raises(big):
    """Resident-only tuning of an over-budget dictionary must fail with a
    pointer at the budget, not an opaque empty-min error."""
    words, _, _ = corpus.build_corpus(n_words=64, seed=3)
    e = jnp.asarray(corpus.encode_corpus(words))
    with pytest.raises(ValueError, match="residency budget"):
        ops.autotune_stem_fused(e, big, residencies=("resident",),
                                iters=1, interpret=True)
