"""FlashAttention Pallas kernel vs jnp oracle: shape/dtype/block sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention


def _rand(b, h, t, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, t, d)).astype(np.float32) * 0.5)
    return mk().astype(dtype), mk().astype(dtype), mk().astype(dtype)


TOLS = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,block_q,block_k", [
    (128, 128, 128), (256, 128, 128), (256, 64, 128), (512, 128, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(dtype, t, block_q, block_k, causal):
    q, k, v = _rand(2, 3, t, 64, dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=True)
    want = kref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOLS[dtype])


@pytest.mark.parametrize("d", [32, 64, 128])
def test_flash_head_dims(d):
    q, k, v = _rand(1, 2, 128, d, jnp.float32, seed=d)
    got = flash_attention(q, k, v, interpret=True)
    want = kref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    h=st.integers(1, 3),
    seed=st.integers(0, 100),
)
def test_flash_property(t_blocks, h, seed):
    t = 64 * t_blocks
    q, k, v = _rand(1, h, t, 32, jnp.float32, seed=seed)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = kref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_causality():
    """Future tokens must not influence outputs."""
    q, k, v = _rand(1, 1, 128, 32, jnp.float32)
    out1 = flash_attention(q, k, v, interpret=True)
    k2 = k.at[:, :, 100:].set(99.0)  # perturb only future keys
    v2 = v.at[:, :, 100:].set(99.0)
    out2 = flash_attention(q, k2, v2, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, :100]),
                               np.asarray(out2[:, :, :100]), rtol=1e-6)
