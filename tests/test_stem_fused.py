"""Megakernel (stages 1-5 in one pallas_call) parity + launch-count tests.

No hypothesis dependency: this module must always collect, so the
single-launch stemmer keeps kernel-level coverage even on minimal
dev environments.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, pyref, stemmer
from repro.data import pipeline as data_pipeline
from repro.kernels import ops
from repro.kernels import stem_fused as sf
from repro.kernels import stem_match as sm

MATCHES = ("bank", "bsearch")


@pytest.fixture(scope="module")
def dicts():
    d = corpus.build_dictionary(n_tri=800, n_quad=100, seed=7)
    return d, stemmer.RootDictArrays.from_rootdict(d)


@pytest.fixture(scope="module")
def corpus_enc():
    words, _, _ = corpus.build_corpus(n_words=512, seed=11)
    return words, jnp.asarray(corpus.encode_corpus(words))


# ---------------------------------------------------------------------------
# parity: megakernel == core jnp == pyref, both match strategies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("infix", [True, False])
def test_megakernel_matches_core(dicts, corpus_enc, infix, match):
    _, da = dicts
    _, enc = corpus_enc
    r1, s1 = ops.extract_roots_fused(enc, da, infix=infix, match=match,
                                     interpret=True)
    r2, s2 = stemmer.stem_batch(enc, da, infix=infix)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("infix", [True, False])
def test_megakernel_matches_pyref(dicts, corpus_enc, infix, match):
    d, da = dicts
    words, enc = corpus_enc
    roots, srcs = ops.extract_roots_fused(enc, da, infix=infix, match=match,
                                          interpret=True)
    roots, srcs = np.asarray(roots), np.asarray(srcs)
    for i, w in enumerate(words[:128]):
        want_root, want_src = pyref.extract_root(np.asarray(enc[i]), d,
                                                 infix=infix)
        got = tuple(int(c) for c in roots[i] if c)
        assert got == want_root, w
        assert int(srcs[i]) == want_src, w


@pytest.mark.parametrize("block_b", [64, 128, 512])
def test_megakernel_block_sweep(dicts, corpus_enc, block_b):
    _, da = dicts
    _, enc = corpus_enc
    r1, s1 = ops.extract_roots_fused(enc, da, block_b=block_b, interpret=True)
    r2, s2 = stemmer.stem_batch(enc, da)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# ---------------------------------------------------------------------------
# single-launch property
# ---------------------------------------------------------------------------
def test_megakernel_is_single_launch(dicts, monkeypatch):
    """The infix path must trace exactly ONE pallas_call."""
    _, da = dicts
    calls = []
    real = sf.pl.pallas_call

    def counting(*a, **kw):
        calls.append(kw.get("grid"))
        return real(*a, **kw)

    monkeypatch.setattr(sf.pl, "pallas_call", counting)
    # unique batch size -> fresh trace under jit, so the counter fires
    words, _, _ = corpus.build_corpus(n_words=97, seed=23)
    enc = jnp.asarray(corpus.encode_corpus(words))
    ops.extract_roots_fused(enc, da, infix=True, block_b=64, interpret=True)
    assert len(calls) == 1, calls


# ---------------------------------------------------------------------------
# in-kernel sorted search building block
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,r", [(1, 1), (5, 64), (300, 500), (1024, 2048)])
def test_dict_match_bsearch_shapes(n, r):
    rng = np.random.default_rng(n * 1000 + r)
    dict_keys = jnp.asarray(
        np.unique(rng.integers(0, 2**24, size=r)).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 2**24, size=n).astype(np.int32))
    keys = keys.at[: n // 2].set(dict_keys[: max(1, min(n // 2, r))][: n // 2])
    got = sm.dict_match_bsearch_pallas(keys, dict_keys, interpret=True)
    want = stemmer.match_dense(keys, dict_keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bsearch_hit_boundaries():
    """First/last/absent keys around the sentinel padding."""
    d = jnp.asarray(np.array([3, 9, 11, 200, 2**24 - 1], np.int32))
    flat = sm.pad_dict_sorted(d).reshape(-1)
    keys = jnp.asarray(np.array([0, 3, 4, 9, 199, 200, 2**24 - 1, 2**24 - 2],
                                np.int32))
    got = np.asarray(sm.bsearch_hit(flat, keys))
    np.testing.assert_array_equal(
        got, [False, True, False, True, False, True, True, False])


# ---------------------------------------------------------------------------
# fused backend through the public APIs
# ---------------------------------------------------------------------------
def test_fused_backend_in_core_stemmer(dicts, corpus_enc):
    _, da = dicts
    _, enc = corpus_enc
    r1, s1 = stemmer.stem_batch(enc, da, backend="fused")
    r2, s2 = stemmer.stem_batch(enc, da, backend="sorted")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_fused_backend_in_stem_pipelined(dicts, corpus_enc):
    _, da = dicts
    _, enc = corpus_enc
    r1, s1 = stemmer.stem_pipelined(enc, da, backend="fused", microbatch=128)
    r2, s2 = stemmer.stem_batch(enc, da)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_fused_backend_in_morph_preprocessor():
    words = ["سيلعبون", "يدرسون", "قال", "فتزحزحت"]
    pre_s = data_pipeline.MorphPreprocessor(n_tri=500, n_quad=60)
    pre_f = data_pipeline.MorphPreprocessor(n_tri=500, n_quad=60,
                                            backend="fused")
    toks_s, ids_s = pre_s(words)
    toks_f, ids_f = pre_f(words)
    np.testing.assert_array_equal(toks_s, toks_f)
    np.testing.assert_array_equal(ids_s, ids_f)
    assert (ids_f > 0).all()


@pytest.mark.parametrize("infix", [True, False])
def test_multilaunch_baseline_matches_core(dicts, infix):
    """The pre-megakernel 6-launch path stays correct — it is the baseline
    behind the fused-vs-multilaunch benchmark ratio."""
    _, da = dicts
    words, _, _ = corpus.build_corpus(n_words=300, seed=5)
    enc = jnp.asarray(corpus.encode_corpus(words))
    r1, s1 = ops.extract_roots_multilaunch(enc, da, infix=infix,
                                           interpret=True)
    r2, s2 = stemmer.stem_batch(enc, da, infix=infix)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_empty_batch(dicts):
    _, da = dicts
    root, src = ops.extract_roots_fused(
        jnp.zeros((0, 16), jnp.int32), da, interpret=True)
    assert root.shape == (0, 4) and src.shape == (0,)


def test_unknown_match_strategy_raises(dicts, corpus_enc):
    _, da = dicts
    _, enc = corpus_enc
    with pytest.raises(ValueError, match="match strategy"):
        ops.extract_roots_fused(enc, da, match="nope", interpret=True)


def test_autotune_returns_valid_config(dicts):
    _, da = dicts
    words, _, _ = corpus.build_corpus(n_words=256, seed=3)
    enc = jnp.asarray(corpus.encode_corpus(words))
    cfg = ops.autotune_stem_fused(enc, da, block_bs=(64, 128),
                                  matches=("bsearch",), iters=1,
                                  interpret=True)
    assert cfg["block_b"] in (64, 128) and cfg["match"] == "bsearch"
    assert all(t > 0 for t in cfg["timings"].values())
