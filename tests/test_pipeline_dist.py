"""Device-pipeline runtime test (5 forced host devices via subprocess —
the main pytest session must keep the default single device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=5"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import alphabet as ab
    from repro.core import corpus, stemmer
    from repro.dist import pipeline

    mesh = jax.make_mesh((5,), ("stage",))
    roots = corpus.build_dictionary(n_tri=400, n_quad=50)
    da = stemmer.RootDictArrays.from_rootdict(roots)
    words, _, _ = corpus.build_corpus(n_words=32, seed=4)
    enc = jnp.asarray(corpus.encode_corpus(words))
    m, mb = 4, 8
    bundle = {
        "words": enc.reshape(m, mb, ab.MAXLEN),
        "keys": jnp.zeros((m, mb, 32), jnp.int32),
        "valid": jnp.zeros((m, mb, 32), jnp.int32),
        "root": jnp.zeros((m, mb, 4), jnp.int32),
        "source": jnp.zeros((m, mb), jnp.int32),
    }
    out = pipeline.pipeline_map(pipeline.stemmer_stage_fns(da), bundle, mesh,
                                axis="stage")
    ref_roots, ref_src = stemmer.stem_batch(enc, da)
    np.testing.assert_array_equal(
        np.asarray(out["root"]).reshape(-1, 4), np.asarray(ref_roots))
    np.testing.assert_array_equal(
        np.asarray(out["source"]).reshape(-1), np.asarray(ref_src))
    print("PIPELINE_OK")
""")


def test_pipeline_map_five_stages():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in proc.stdout, proc.stderr[-2000:]
