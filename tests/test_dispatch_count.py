"""ops.dispatch_count() accounting across every launch path.

The counter backs the launch_overhead benchmark and the megabatch CI
check; these tests pin its semantics on the fused, sharded, persistent
and index paths, and the *_order pair proves the conftest fixture
isolates the counter between tests regardless of collection order.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, stemmer
from repro.kernels import ops
from repro.kernels import stem_fused as sf


@pytest.fixture(scope="module")
def small():
    d = corpus.build_dictionary(n_tri=200, n_quad=30, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=96, seed=1)
    return jnp.asarray(corpus.encode_corpus(words)), arrays


def test_fused_counts_planned_launches(small):
    enc, arrays = small
    assert ops.dispatch_count() == 0
    ops.extract_roots_fused(enc, arrays, block_b=32)
    assert ops.dispatch_count() == sf.planned_launches(
        enc.shape[0], arrays, block_b=32)
    ops.extract_roots_fused(enc, arrays, block_b=32)
    assert ops.dispatch_count() == 2 * sf.planned_launches(
        enc.shape[0], arrays, block_b=32)


def test_sharded_counts_per_device(small):
    """The sharded wrapper books n_dev x the per-shard launch plan (a
    1-device mesh in-process; the 4-device path is asserted in the
    test_index_sharded subprocess)."""
    from repro.launch import mesh as mesh_mod

    enc, arrays = small
    mesh = mesh_mod.make_data_mesh(1)
    ops.extract_roots_sharded(enc, arrays, mesh, block_b=32)
    assert ops.dispatch_count() == sf.planned_launches(
        enc.shape[0], arrays, block_b=32)


def test_persistent_counts_one_launch(small):
    """Resident persistent serving = ONE descriptor-ring launch no
    matter how many batch tiles it retires."""
    enc, arrays = small
    root, source, flags = ops.extract_roots_persistent(enc, arrays,
                                                       block_b=32)
    assert ops.dispatch_count() == 1
    assert flags.shape[0] == -(-enc.shape[0] // 32)
    want_r, want_s = stemmer.stem_batch(enc, arrays)
    np.testing.assert_array_equal(np.asarray(root), np.asarray(want_r))
    np.testing.assert_array_equal(np.asarray(source), np.asarray(want_s))


def test_persistent_streamed_chunks_count(small):
    """A streamed persistent launch whose visit table busts the SMEM
    budget chunks into several dispatches — the counter must report the
    actual chunk count, same as planned_launches."""
    enc, arrays = small
    grown = corpus.grow_root_arrays(arrays, 70_000, seed=3)
    n_tiles = sf.dict_tile_count(grown, 8)
    budget = 2 * n_tiles          # 2 batch tiles per chunk; 96/32 -> 2 calls
    planned = sf.planned_launches(enc.shape[0], grown, block_b=32,
                                  residency="streamed", persistent=True,
                                  visit_budget=budget)
    assert planned > 1
    root, _, _ = ops.extract_roots_persistent(
        enc, grown, block_b=32, residency="streamed", visit_budget=budget)
    assert ops.dispatch_count() == planned
    want_r, _ = stemmer.stem_batch(enc, grown)
    np.testing.assert_array_equal(np.asarray(root), np.asarray(want_r))


def test_index_counts_stemmer_plus_postings(small):
    from repro import index as ix

    enc, arrays = small
    vocab = ix.build_vocab(arrays)
    doc = np.zeros(enc.shape[0], np.int32)
    pos = np.arange(enc.shape[0], dtype=np.int32)
    ops.build_root_index(enc, arrays, vocab, doc, pos, block_b=32,
                         block_w=32)
    assert ops.dispatch_count() == sf.planned_launches(
        enc.shape[0], arrays, block_b=32) + 1


# -- the conftest fixture must isolate the counter between tests ---------
# (pytest runs a module's tests in definition order: _a dirties the
# counter, _b only passes if the autouse reset ran in between)
def test_counter_isolation_order_a(small):
    enc, arrays = small
    ops.extract_roots_fused(enc, arrays, block_b=32)
    assert ops.dispatch_count() > 0


def test_counter_isolation_order_b():
    assert ops.dispatch_count() == 0
