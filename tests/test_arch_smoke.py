"""Per-architecture smoke tests: reduced same-family configs, one forward
+ one train-grad + one prefill->decode step on CPU; asserts shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as model_mod
from repro.models import params as pm

ARCHS = sorted(configs.ARCHS)


def make_batch(cfg, rng, batch=2, seq=32):
    if cfg.n_codebooks:
        tokens = rng.integers(0, cfg.vocab, size=(batch, seq, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab, size=(batch, seq))
    out = {
        "tokens": jnp.asarray(tokens.astype(np.int32)),
        "labels": jnp.asarray(tokens.astype(np.int32)),
    }
    if cfg.n_cross_layers:
        out["vision_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_seq, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)
    return out


@pytest.fixture(scope="module")
def built():
    cache = {}

    def build(name):
        if name not in cache:
            cfg = configs.smoke_config(configs.get_config(name))
            spec = model_mod.model_spec(cfg)
            params = pm.init_params(spec, jax.random.key(0))
            cache[name] = (cfg, params)
        return cache[name]

    return build


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, params = built(arch)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)
    out = model_mod.forward(params, cfg, batch["tokens"],
                            vision_embeds=batch.get("vision_embeds"))
    b, t = batch["tokens"].shape[:2]
    if cfg.n_codebooks:
        assert out.logits.shape == (b, t, cfg.n_codebooks, cfg.vocab)
    else:
        assert out.logits.shape == (b, t, cfg.vocab)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch, built):
    cfg, params = built(arch)
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng)

    loss, grads = jax.value_and_grad(model_mod.loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients produced"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, built):
    cfg, params = built(arch)
    rng = np.random.default_rng(2)
    b, t = 2, 16
    batch = make_batch(cfg, rng, batch=b, seq=t)

    out = model_mod.forward(params, cfg, batch["tokens"], mode="prefill",
                            vision_embeds=batch.get("vision_embeds"))
    assert out.caches, "prefill produced no caches"

    # splice prefill caches into full-size decode caches
    caches = model_mod.init_caches(cfg, b, cache_len=t + 8)
    caches = _splice(caches, out.caches, t)

    tok = batch["tokens"][:, -1:]
    logits, new_caches = model_mod.decode_step(
        params, cfg, tok, caches, jnp.int32(t))
    if cfg.n_codebooks:
        assert logits.shape == (b, 1, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # caches keep their structure
    jax.tree.map(lambda a, b_: None, caches, new_caches)


def _splice(full, prefill, t):
    """Copy prefilled cache contents into the leading positions of the
    (longer) decode cache along the sequence axis; ssm states copy whole."""

    def merge(dst, src):
        if dst.shape == src.shape:
            return src
        # sequence axis is the one where shapes differ
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), 0, axis=ax)
        return src

    return jax.tree.map(merge, full, prefill)


def test_decode_matches_forward_llama():
    """Greedy decode step logits == teacher-forced forward logits."""
    cfg = configs.smoke_config(configs.get_config("llama3-8b"))
    spec = model_mod.model_spec(cfg)
    params = pm.init_params(spec, jax.random.key(1))
    rng = np.random.default_rng(3)
    b, t = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)).astype(np.int32))

    full = model_mod.forward(params, cfg, tokens)
    caches = model_mod.init_caches(cfg, b, cache_len=t)
    logits = None
    for i in range(t):
        logits, caches = model_mod.decode_step(
            params, cfg, tokens[:, i : i + 1], caches, jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full.logits[:, -1], np.float32),
        rtol=0.06, atol=0.05,
    )


def test_mamba_decode_matches_forward():
    cfg = configs.smoke_config(configs.get_config("falcon-mamba-7b"))
    spec = model_mod.model_spec(cfg)
    params = pm.init_params(spec, jax.random.key(2))
    rng = np.random.default_rng(4)
    b, t = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)).astype(np.int32))

    full = model_mod.forward(params, cfg, tokens)
    caches = model_mod.init_caches(cfg, b, cache_len=t)
    logits = None
    for i in range(t):
        logits, caches = model_mod.decode_step(
            params, cfg, tokens[:, i : i + 1], caches, jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full.logits[:, -1], np.float32),
        rtol=0.06, atol=0.05,
    )


def test_param_counts_full_configs():
    """Full configs land near their nameplate sizes (sanity, no alloc)."""
    expect = {
        "llama3-8b": (7.5e9, 8.5e9),
        "qwen3-moe-235b-a22b": (2.2e11, 2.5e11),
        "deepseek-v2-lite-16b": (1.4e10, 1.8e10),
        "falcon-mamba-7b": (6.5e9, 8.0e9),
        "deepseek-coder-33b": (3.1e10, 3.6e10),
        "qwen2.5-14b": (1.3e10, 1.6e10),
        "gemma-2b": (2.0e9, 3.0e9),
        "hymba-1.5b": (1.2e9, 2.1e9),
        "musicgen-medium": (1.2e9, 1.8e9),
        "llama-3.2-vision-11b": (9.0e9, 1.15e10),
    }
    for arch, (lo, hi) in expect.items():
        cfg = configs.get_config(arch)
        n = pm.count_params(model_mod.model_spec(cfg))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9},{hi/1e9}]"
