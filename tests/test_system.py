"""End-to-end system tests: full paper pipeline, dry-run artifact
integrity, train->serve round trip."""
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs import RunConfig, ShapeConfig, shapes_for
from repro.core import accuracy, corpus, pyref, stemmer
from repro.data import pipeline as data_pipeline
from repro.models import model as model_mod
from repro.models import params as pm
from repro.serve.engine import ServeEngine
from repro.train import loop

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "results"


# ---------------------------------------------------------------------------
# the paper's full pipeline: corpus -> stemmer -> accuracy
# ---------------------------------------------------------------------------
def test_end_to_end_paper_pipeline():
    words, truths, _ = corpus.build_corpus(n_words=1500, seed=21)
    roots = corpus.build_dictionary(n_tri=1000, n_quad=120)
    rep_with = accuracy.evaluate(words, truths, roots, infix=True)
    rep_wo = accuracy.evaluate(words, truths, roots, infix=False)
    # the paper's central accuracy claim: infix processing helps, a lot
    assert rep_with.accuracy > rep_wo.accuracy + 0.1
    # small corpus -> tail roots may only appear in unrecoverable forms
    assert rep_with.root_recall > 0.75
    assert rep_with.root_recall > rep_wo.root_recall


def test_infix_sources_actually_fire():
    words, truths, _ = corpus.build_corpus(n_words=2000, seed=5)
    roots = corpus.build_dictionary()
    rep = accuracy.evaluate(words, truths, roots, infix=True)
    assert rep.by_source[pyref.SRC_RESTORED] > 0
    assert rep.by_source[pyref.SRC_DEINFIX_TRI] > 0


# ---------------------------------------------------------------------------
# train -> serve round trip on a smoke model
# ---------------------------------------------------------------------------
def test_train_then_serve_roundtrip(tmp_path):
    cfg = configs.smoke_config(configs.get_config("llama3-8b"))
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                    remat="none", learning_rate=3e-3, lr_warmup=5)
    data = data_pipeline.synthetic_lm_batches(cfg.vocab, 4, 32,
                                              effective_vocab=16)
    params = pm.init_params(model_mod.model_spec(cfg), jax.random.key(3))
    result = loop.fit(cfg, run, data, params=params, steps=25,
                      ckpt_dir=tmp_path, ckpt_every=25)
    assert result.losses[-1] < result.losses[0]

    # restore the trained params and serve them
    from repro.train import checkpoint, optimizer

    state = checkpoint.restore(
        tmp_path, 25,
        {"params": params, "opt": optimizer.init(params)})
    eng = ServeEngine(cfg, state["params"], max_batch=2, cache_len=64)
    rid = eng.submit(np.asarray([1, 2, 3], np.int32), max_new=4)
    eng.run_until_drained()
    assert len(eng.result(rid).tokens_out) == 4


# ---------------------------------------------------------------------------
# dry-run artifact integrity (produced by launch/dryrun.py --all)
# ---------------------------------------------------------------------------
def _cells():
    out = []
    for arch in sorted(configs.ARCHS):
        cfg = configs.get_config(arch)
        for sh in shapes_for(cfg):
            out.append((arch, sh))
    return out


@pytest.mark.skipif(not RESULTS.exists() or not list(RESULTS.glob("dryrun_*.json")),
                    reason="dry-run results not generated")
def test_dryrun_records_complete():
    cells = _cells()
    assert len(cells) == 32  # 10x3 + 2 long_500k
    for arch, sh in cells:
        for mesh in ("16x16", "2x16x16"):
            f = RESULTS / f"dryrun_{arch}_{sh}_{mesh}.json"
            assert f.exists(), f"missing dry-run cell {f.name}"
            rec = json.loads(f.read_text())
            assert rec["compile_s"] > 0
            if mesh == "16x16":
                rf = rec["roofline"]
                assert rf["bottleneck"] in ("compute", "memory", "collective")
                assert all(rf[k] >= 0 for k in
                           ("compute_s", "memory_s", "collective_s"))
                assert rec["hlo_flops"] > 0
                assert rec["model_flops"] > 0


@pytest.mark.skipif(not RESULTS.exists() or not list(RESULTS.glob("dryrun_*.json")),
                    reason="dry-run results not generated")
def test_hillclimb_profiles_recorded():
    base = json.loads(
        (RESULTS / "dryrun_llama3-8b_train_4k_16x16.json").read_text())
    opt = json.loads(
        (RESULTS / "dryrun_llama3-8b_train_4k_16x16_fsdp2d.json").read_text())
    # the §Perf-1 headline: fsdp2d at least 3x better on the collective term
    assert opt["roofline"]["collective_s"] * 3 < base["roofline"]["collective_s"]
