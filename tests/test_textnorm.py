"""Normalisation + segmentation rule parity: the host string pipeline,
the jnp reference, and the Pallas text front-end kernel must agree on
every rule in the shared tables (core/textnorm.py) — per diacritic, per
alef variant, per clitic pattern, per function word."""
import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import textnorm as tn
from repro.kernels import text_frontend as tf


def _tile(text: str, t: int = 0) -> np.ndarray:
    chars, _, _ = tn.coalesce_docs([text])
    t = t or max(128, -(-chars.shape[0] // 128) * 128)
    tile = np.zeros(t, np.int32)
    tile[:chars.shape[0]] = chars
    return tile


def three_way(text: str, block_w: int = 128):
    """Run host / jnp-reference / kernel on one document, assert parity,
    return the host (words, spans)."""
    words_py, spans_py = tn.analyze_text_py(text)
    tile = _tile(text)
    words_j, geo = tn.frontend_reference(tile, block_w=block_w)
    n = int(geo.n_words)
    assert n == words_py.shape[0]
    np.testing.assert_array_equal(np.asarray(words_j)[:n], words_py)
    np.testing.assert_array_equal(np.asarray(geo.spans)[:n], spans_py)
    words_k = tf.text_frontend_pallas(tile, geo.starts, geo.lens,
                                      block_w=block_w, interpret=True)
    np.testing.assert_array_equal(np.asarray(words_k),
                                  np.asarray(words_j))
    # zero rows past n_words (the stemmer maps them to SRC_NONE)
    assert not np.asarray(words_j)[n:].any()
    return words_py, spans_py


# ---------------------------------------------------------------------------
# table-level rule checks (host side: the single source of truth)
# ---------------------------------------------------------------------------
def test_class_lut_matches_classify_cp_everywhere():
    for off in range(0x100):
        assert tn.CLASS_LUT[off] == tn.classify_cp(0x0600 + off)
    # off-page codepoints are separators by construction
    for cp in (0x20, 0x41, 0x39, 0x5FF, 0x700, 0x1F600):
        assert tn.classify_cp(cp) == tn.CLS_SEP


def test_every_diacritic_and_tatweel_is_a_mark():
    for cp in sorted(ab.DIACRITICS) + [ab.TATWEEL]:
        assert tn.classify_cp(cp) == tn.CLS_MARK, hex(cp)
        assert ab.normalise("د" + chr(cp) + "رس") == "درس", hex(cp)


def test_every_normalise_rule_collapses():
    for src, dst in ab.NORMALISE.items():
        assert tn.classify_cp(src) == ab.CP_TO_CODE[dst], hex(src)
        assert ab.normalise(chr(src)) == chr(dst)
    # the satellite rules named in the issue, explicitly
    assert ab.normalise("ٱ") == "ا"          # alef wasla
    assert ab.normalise("مـــد") == "مد"     # tatweel
    assert ab.normalise("مدرسة") == "مدرست"  # taa marbuta -> teh


def test_encode_is_a_thin_wrapper_over_the_tables():
    # encode_word == normalise + CP_TO_CODE; textnorm letters_py must
    # agree on plain (unsegmented) words
    for w in ("مدرسة", "ٱلرَّحْمَٰنِ", "وَالْكِتَابُ", "مـــدرسة"):
        via_alphabet = [int(c) for c in ab.encode_word(w) if c]
        via_textnorm = tn.letters_py(tuple(map(ord, w)))
        assert via_alphabet == via_textnorm, w


def test_jnp_classify_matches_host_over_page_and_ascii():
    cps = np.asarray(list(range(0x0600, 0x0700))
                     + list(range(0, 0x80)) + [0x5FF, 0x700], np.int32)
    import jax.numpy as jnp

    got = np.asarray(tn.classify_codes(jnp.asarray(cps),
                                       jnp.asarray(tn.CLASS_LUT)))
    want = np.asarray([tn.classify_cp(int(c)) for c in cps], np.int32)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# three-way parity per rule family
# ---------------------------------------------------------------------------
def test_parity_every_diacritic_in_context():
    # one word per mark: د<mark>رس — all three paths must strip it
    words = ["د" + chr(cp) + "رس" for cp in sorted(ab.DIACRITICS)]
    rows, _ = three_way(" ".join(words))
    want = ab.encode_word("درس")
    for i, row in enumerate(rows):
        np.testing.assert_array_equal(row, want)


def test_parity_alef_variants_and_taa_marbuta():
    rows, _ = three_way("آمن أمن إمن ٱمن مدرسة مـــد")
    np.testing.assert_array_equal(rows[0], ab.encode_word("امن"))
    np.testing.assert_array_equal(rows[1], ab.encode_word("امن"))
    np.testing.assert_array_equal(rows[2], ab.encode_word("امن"))
    np.testing.assert_array_equal(rows[3], ab.encode_word("امن"))
    np.testing.assert_array_equal(rows[4], ab.encode_word("مدرست"))
    np.testing.assert_array_equal(rows[5], ab.encode_word("مد"))


@pytest.mark.parametrize("pro", tn.PROCLITICS)
def test_parity_each_proclitic_strips(pro):
    base = "قلم"                      # 3 letters: always >= MIN_STEM
    rows, _ = three_way(pro + base)
    np.testing.assert_array_equal(rows[0], ab.encode_word(base))


@pytest.mark.parametrize("enc", tn.ENCLITICS)
def test_parity_each_enclitic_strips(enc):
    base = "قلم"
    rows, _ = three_way(base + enc)
    np.testing.assert_array_equal(rows[0], ab.encode_word(base))


def test_parity_longest_match_precedence():
    rows, _ = three_way("والقلم للعلم قلمهما وكتبها كتبهما")
    np.testing.assert_array_equal(rows[0], ab.encode_word("قلم"))   # وال not و
    np.testing.assert_array_equal(rows[1], ab.encode_word("علم"))   # لل not ل
    np.testing.assert_array_equal(rows[2], ab.encode_word("قلم"))   # هما not ه/ها
    np.testing.assert_array_equal(rows[3], ab.encode_word("كتب"))   # و + ها
    # single pass, proclitic first: ك strips, then هما is blocked by the
    # MIN_STEM guard (5 - 3 < 3) — the spec'd order, not a bug
    np.testing.assert_array_equal(rows[4], ab.encode_word("تبهما"))


def test_parity_min_stem_guard():
    # stripping must leave >= 3 letters: none of these strip
    rows, _ = three_way("به لك كمن بكر")
    np.testing.assert_array_equal(rows[0], ab.encode_word("به"))
    np.testing.assert_array_equal(rows[1], ab.encode_word("لك"))
    np.testing.assert_array_equal(rows[2], ab.encode_word("كمن"))
    np.testing.assert_array_equal(rows[3], ab.encode_word("بكر"))


def test_parity_every_function_word_is_exempt():
    fws = list(tn.FUNCTION_WORDS)
    rows, _ = three_way(" ".join(fws))
    want = ab.encode_batch(fws)
    np.testing.assert_array_equal(rows, want)


def test_function_word_exemption_vs_stripping():
    # the Snippet-1 example: كانت is exempt; a non-function word with the
    # same shape (كتبت -> ك is NOT stripped as remainder < MIN_STEM after
    # a match? no: كتبت has 4 letters, ك strips to تبت) is not
    rows, _ = three_way("كانت كتبت")
    np.testing.assert_array_equal(rows[0], ab.encode_word("كانت"))
    np.testing.assert_array_equal(rows[1], ab.encode_word("تبت"))


def test_fw_table_layout():
    # sorted, unique, sentinel-padded pow2 >= one lane row
    assert tn.FW_FLAT.shape[0] >= 128
    assert tn.FW_FLAT.shape[0] & (tn.FW_FLAT.shape[0] - 1) == 0
    keys = tn.FW_KEYS
    assert (np.diff(keys) > 0).all()
    assert (tn.FW_FLAT[len(keys):] == tn.FW_SENTINEL).all()
    assert int(keys[-1]) < int(tn.FW_SENTINEL)


def test_quranic_annotation_marks_strip():
    # U+06D6.. small high signs ride along in Quranic text
    rows, _ = three_way("قلمۖ دۡرس")
    np.testing.assert_array_equal(rows[0], ab.encode_word("قلم"))
    np.testing.assert_array_equal(rows[1], ab.encode_word("درس"))
