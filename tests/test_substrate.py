"""Substrate tests: optimizer, checkpoint-restart, train loop fault
tolerance, grad compression, data pipeline, serving engine."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import RunConfig, ShapeConfig
from repro.data import pipeline as data_pipeline
from repro.dist import compression
from repro.models import model as model_mod
from repro.models import params as pm
from repro.serve.engine import ServeEngine
from repro.train import checkpoint, loop, optimizer, train_step as ts


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.smoke_config(configs.get_config("llama3-8b"))
    params = pm.init_params(model_mod.model_spec(cfg), jax.random.key(0))
    return cfg, params


def _run(cfg):
    return RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                     remat="none", learning_rate=3e-3, lr_warmup=5)


def _batches(cfg, batch=4, seq=32, seed=0):
    return data_pipeline.synthetic_lm_batches(cfg.vocab, batch, seq, seed,
                                              effective_vocab=32)


# ---------------------------------------------------------------------------
# optimizer + training
# ---------------------------------------------------------------------------
def test_train_loss_decreases(tiny):
    cfg, params = tiny
    run = _run(cfg)
    step = jax.jit(ts.make_train_step(cfg, run))
    opt = optimizer.init(params)
    data = _batches(cfg)
    losses = []
    for _ in range(40):
        params, opt, m = step(params, opt, next(data))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[::8]


def test_microbatched_grads_match(tiny):
    cfg, params = tiny
    batch = next(_batches(cfg, batch=4))
    run1 = _run(cfg)
    run4 = RunConfig(model=cfg, shape=run1.shape, remat="none",
                     learning_rate=1e-3, microbatches=4)
    s1 = ts.make_train_step(cfg, run1)
    s4 = ts.make_train_step(cfg, run4)
    opt = optimizer.init(params)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 2e-2


def test_cosine_lr_schedule():
    lrs = [float(optimizer.cosine_lr(jnp.int32(s), peak=1e-3)) for s in
           [0, 50, 100, 5000, 9999]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]        # decay
    assert lrs[4] >= 1e-4 * 0.99             # floor


# ---------------------------------------------------------------------------
# checkpoint / restart
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    opt = optimizer.init(params)
    checkpoint.save(tmp_path, 7, {"params": params, "opt": opt})
    assert checkpoint.latest_step(tmp_path) == 7
    restored = checkpoint.restore(tmp_path, 7, {"params": params, "opt": opt})
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        {"params": params, "opt": opt}, restored)


def test_checkpoint_async_and_gc(tmp_path, tiny):
    cfg, params = tiny
    t = None
    for s in (1, 2, 3, 4, 5):
        t = checkpoint.save(tmp_path, s, {"p": params}, keep=2, async_=True)
    t.join()
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.glob("step_*"))
    assert steps[-1] == 5 and len(steps) <= 3


def test_fit_resume_continuity(tmp_path, tiny):
    """Kill training mid-run; resume must continue from the checkpoint."""
    cfg, _ = tiny
    run = _run(cfg)
    r1 = loop.fit(cfg, run, _batches(cfg, seed=1), steps=6,
                  ckpt_dir=tmp_path, ckpt_every=3, seed=1)
    assert r1.steps_run == 6
    # "crash" after step 6 (checkpoint exists at 6); rerun to 10
    r2 = loop.fit(cfg, run, _batches(cfg, seed=2), steps=10,
                  ckpt_dir=tmp_path, ckpt_every=3, seed=1)
    assert r2.resumed_from == 6
    assert r2.steps_run == 4
    assert r2.final_step == 10


def test_fit_preemption_checkpoint(tmp_path, tiny):
    cfg, _ = tiny
    run = _run(cfg)

    calls = {"n": 0}

    def on_metrics(step, m):
        calls["n"] += 1
        if calls["n"] == 2:  # simulate a SIGTERM mid-run
            os.kill(os.getpid(), signal.SIGTERM)

    r = loop.fit(cfg, run, _batches(cfg, seed=3), steps=50,
                 ckpt_dir=tmp_path, ckpt_every=1000, seed=3,
                 on_metrics=on_metrics)
    assert r.steps_run <= 3
    assert checkpoint.latest_step(tmp_path) == r.final_step


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_ef_unbiased_over_time():
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)) * 1e-3
    errs = [jnp.zeros_like(true)]
    acc_q = jnp.zeros_like(true)
    for _ in range(50):
        deqs, errs = compression.compress_decompress([true], errs)
        acc_q = acc_q + deqs[0]
    # error feedback keeps the long-run average unbiased
    np.testing.assert_allclose(np.asarray(acc_q) / 50, np.asarray(true),
                               atol=1e-6)


def test_quantise_range():
    x = jnp.asarray(np.linspace(-3, 3, 1000, dtype=np.float32))
    q, scale = compression.quantise_tensor(x)
    assert int(jnp.max(q)) == 127 and int(jnp.min(q)) == -127
    np.testing.assert_allclose(np.asarray(q, np.float32) * float(scale),
                               np.asarray(x), atol=float(scale) * 0.51)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_morph_preprocessor_roots():
    pre = data_pipeline.MorphPreprocessor(n_tri=500, n_quad=60)
    toks, ids = pre(["سيلعبون", "يدرسون", "قال"])
    assert toks.shape == (3, 16)
    assert (ids > 0).all()  # all three have extractable roots


def test_morph_lm_stream_shapes():
    it = data_pipeline.morph_lm_batches(batch_words=64, seq=32)
    b = next(it)
    assert b["tokens"].shape == (1, 32)
    assert b["labels"].shape == (1, 32)
    assert b["tokens"].max() <= b["vocab"]


def test_morph_lm_root_ids_align_with_chunk():
    """Regression: each chunk must carry exactly the root ids of the words
    whose characters appear in it, not the whole-batch array."""
    from repro.core import corpus

    pre = data_pipeline.MorphPreprocessor(n_tri=500, n_quad=60)
    words, _, _ = corpus.build_corpus(n_words=64, seed=0)  # epoch-0 corpus
    _, all_ids = pre(words)
    it = data_pipeline.morph_lm_batches(batch_words=64, seq=32, preproc=pre)
    spans = []
    for _ in range(8):
        b = next(it)
        w0, w1 = b["word_span"]
        assert 0 <= w0 < w1 <= len(words)
        assert b["root_ids"].shape == (w1 - w0,)
        assert w1 - w0 < len(words)  # the old bug shipped the whole batch
        np.testing.assert_array_equal(b["root_ids"], all_ids[w0:w1])
        spans.append((w0, w1))
    # consecutive chunks advance through the corpus without gaps (the
    # boundary word may straddle two chunks)
    for (_, a1), (b0, _) in zip(spans, spans[1:]):
        assert b0 in (a1 - 1, a1)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------
def test_engine_continuous_batching(tiny):
    cfg, params = tiny
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab, 5), max_new=4)
            for _ in range(4)]  # 4 requests > 2 slots -> queueing
    eng.run_until_drained()
    for rid in rids:
        req = eng.result(rid)
        assert req is not None and req.done
        assert len(req.tokens_out) == 4
        assert all(0 <= t < cfg.vocab for t in req.tokens_out)


def test_engine_max_new_exact(tiny):
    """Regression: a freshly admitted slot used to get a same-tick decode
    before its doneness check, so max_new=1 returned 2 tokens."""
    cfg, params = tiny
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    rng = np.random.default_rng(1)
    rids = {n: eng.submit(rng.integers(0, cfg.vocab, 4), max_new=n)
            for n in (1, 2, 5)}
    eng.run_until_drained()
    for n, rid in rids.items():
        req = eng.result(rid)
        assert req is not None and req.done
        assert len(req.tokens_out) == n, (n, req.tokens_out)
    # prefill always emits one token, so max_new < 1 is unsatisfiable
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(np.asarray([1, 2], np.int32), max_new=0)


def test_engine_matches_direct_decode(tiny):
    """Engine output == straight greedy decode_step loop."""
    cfg, params = tiny
    prompt = np.asarray([5, 9, 2, 7], np.int32)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    rid = eng.submit(prompt, max_new=3)
    eng.run_until_drained()
    got = eng.result(rid).tokens_out

    caches = model_mod.init_caches(cfg, 1, cache_len=64)
    toks = list(prompt)
    out = []
    logits = None
    for i, t in enumerate(toks):
        logits, caches = model_mod.decode_step(
            params, cfg, jnp.asarray([[t]], jnp.int32), caches, jnp.int32(i))
    for j in range(3):
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        logits, caches = model_mod.decode_step(
            params, cfg, jnp.asarray([[nxt]], jnp.int32), caches,
            jnp.int32(len(toks) + j))
    assert got == out


# ---------------------------------------------------------------------------
# int8 KV cache (beyond-paper serving feature)
# ---------------------------------------------------------------------------
def test_int8_kv_decode_matches_bf16():
    import dataclasses

    import jax.numpy as jnp

    from repro import configs as cfgs
    from repro.models import model as mm
    from repro.models import params as pmod

    cfg = cfgs.smoke_config(cfgs.get_config("llama3-8b"))
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = pmod.init_params(mm.model_spec(cfg), jax.random.key(5))
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 10)).astype(np.int32))

    def run(c):
        caches = mm.init_caches(c, 2, cache_len=10)
        logits = None
        for i in range(10):
            logits, caches = mm.decode_step(
                params, c, toks[:, i : i + 1], caches, jnp.int32(i))
        return np.asarray(logits, np.float32)

    full = run(cfg)
    quant = run(cfg_q)
    # int8 KV introduces bounded quantisation noise only
    np.testing.assert_allclose(quant, full, rtol=0.2, atol=0.3)
    corr = np.corrcoef(full.ravel(), quant.ravel())[0, 1]
    assert corr > 0.99


def test_quantise_kv_roundtrip():
    from repro.models import attention as attn

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)).astype(np.float32) * 3)
    q, s = attn.quantise_kv(x)
    back = attn.dequantise_kv(q, s, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= np.asarray(s).max() * 0.51
