"""Pipelined streamed-Compare tests (DESIGN.md §5.3).

The streamed megakernel path is an explicitly pipelined sweep: a
scalar-prefetched per-batch-tile tile-visit index (only dictionary tiles
a live candidate key can land in are visited) feeding a num_buffers-deep
make_async_copy DMA ladder. This suite pins:

  - bit-identity with residency="resident" and the core jnp stemmer
    across num_buffers x match x infix x dictionary sizes straddling the
    64K-key VMEM ceiling;
  - adversarial key distributions: every dictionary key in one tile,
    matching keys sitting exactly on tile boundaries, and dictionaries
    no candidate key can land in (empty visit lists);
  - the visit index itself (strictly fewer visits than the full sweep on
    big dictionaries; zero visits when nothing can match; full sweep
    when skip_index=False);
  - the publish-time DictTileSet plumbing (prebuilt tile stream +
    boundary tables through ResolvedRootDict / DictStore) and the
    serving workload's num_buffers / skip_index knobs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import corpus, pyref, stemmer
from repro.kernels import ops
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_fused as sf
from repro.kernels import stem_match as sm

MATCHES = ("bank", "bsearch")


def _assert_parity(got, ref):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


@pytest.fixture(scope="module")
def small():
    d = corpus.build_dictionary(n_tri=600, n_quad=80, seed=9)
    return stemmer.RootDictArrays.from_rootdict(d)


@pytest.fixture(scope="module")
def big(small):
    da = corpus.grow_root_arrays(small, 100_000, seed=2)
    assert sf._loaded_keys(da, True) > sf.MAX_RESIDENT_KEYS
    return da


@pytest.fixture(scope="module")
def enc():
    words, _, _ = corpus.build_corpus(n_words=384, seed=13)
    return jnp.asarray(corpus.encode_corpus(words))


# ---------------------------------------------------------------------------
# parity: ladder depth x match x infix, straddling the VMEM ceiling
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_buffers", [1, 2, 3, 4])
def test_ladder_depth_parity_small(small, enc, num_buffers):
    """Every ladder depth is bit-identical to the resident layout and the
    core stemmer (which is pyref-pinned by test_stemmer.py)."""
    ref = stemmer.stem_batch(enc, small)
    res = ops.extract_roots_fused(enc, small, residency="resident",
                                  interpret=True)
    got = ops.extract_roots_fused(enc, small, residency="streamed",
                                  block_b=128, dict_block_r=2,
                                  num_buffers=num_buffers, interpret=True)
    _assert_parity(res, ref)
    _assert_parity(got, ref)


@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("infix", [True, False])
def test_pipeline_matches_core_past_ceiling(big, enc, infix, match):
    ref = stemmer.stem_batch(enc, big, infix=infix)
    got = ops.extract_roots_fused(enc, big, infix=infix, match=match,
                                  residency="streamed", block_b=128,
                                  num_buffers=2, interpret=True)
    _assert_parity(got, ref)


@pytest.mark.parametrize("match", MATCHES)
@pytest.mark.parametrize("skip_index", [True, False])
def test_skip_index_polarity_parity(small, enc, match, skip_index):
    """skip_index=False (full sweep) and =True run the same ladder kernel
    and must agree bit-for-bit with the resident layout."""
    ref = ops.extract_roots_fused(enc, small, match=match,
                                  residency="resident", interpret=True)
    got = ops.extract_roots_fused(enc, small, match=match,
                                  residency="streamed", block_b=128,
                                  dict_block_r=2, skip_index=skip_index,
                                  num_buffers=3, interpret=True)
    _assert_parity(got, ref)


def test_pipeline_through_public_api_256k(small):
    da = corpus.grow_root_arrays(small, 262_144, seed=5)
    words, _, _ = corpus.build_corpus(n_words=192, seed=17)
    e = jnp.asarray(corpus.encode_corpus(words))
    r1, s1 = stemmer.extract_roots(e, da, backend="fused", num_buffers=4)
    r2, s2 = stemmer.extract_roots(e, da, backend="sorted")
    _assert_parity((r1, s1), (r2, s2))
    assert (np.asarray(s1) != pyref.SRC_NONE).any()


# ---------------------------------------------------------------------------
# the visit index
# ---------------------------------------------------------------------------
def test_skip_index_visits_fewer_tiles_on_big_dict(big, enc):
    on = sf.tile_visit_stats(enc, big, block_b=128, dict_block_r=8,
                             skip_index=True)
    off = sf.tile_visit_stats(enc, big, block_b=128, dict_block_r=8,
                              skip_index=False)
    assert off["visited"] == off["full_sweep"]
    assert on["visited"] < off["visited"]          # the acceptance bar
    assert on["full_sweep"] == off["full_sweep"]


def test_visit_stats_excludes_bi_without_infix(big, enc):
    on = sf.tile_visit_stats(enc, big, infix=True, block_b=128)
    off = sf.tile_visit_stats(enc, big, infix=False, block_b=128)
    assert off["dict_tiles"] < on["dict_tiles"]    # bi tiles not swept


# ---------------------------------------------------------------------------
# adversarial key distributions
# ---------------------------------------------------------------------------
def _arrays(tri=(), quad=(), bi=()):
    def pack(keys):
        return jnp.asarray(sorted(keys) or [-1], jnp.int32)

    return stemmer.RootDictArrays(tri=pack(tri), quad=pack(quad), bi=pack(bi))


def test_all_keys_in_one_tile(small, enc):
    """A dictionary clustered into a single tile: the visit index floors
    at one tile per dictionary and stays bit-identical."""
    # every real tri key, dict_block_r large enough for one tile each
    tri = np.asarray(small.tri).tolist()
    da = _arrays(tri=tri)
    dr = (len(tri) + sm.LANE - 1) // sm.LANE       # one tile holds them all
    st = sf.tile_visit_stats(enc, da, block_b=128, dict_block_r=dr)
    bt = st["batch_tiles"]
    assert st["dict_tiles"] == 3                   # one tile per dictionary
    # at most the tri tile + the quad/bi placeholder tiles per batch tile
    assert bt <= st["visited"] <= 3 * bt
    ref = stemmer.stem_batch(enc, da)
    for nb in (1, 2, 4):
        got = ops.extract_roots_fused(enc, da, residency="streamed",
                                      block_b=128, dict_block_r=dr,
                                      num_buffers=nb, interpret=True)
        _assert_parity(got, ref)
    assert (np.asarray(ref[1]) != pyref.SRC_NONE).any()  # real hits occurred


def test_keys_at_tile_boundaries(enc):
    """Every candidate-producible key IS a dictionary key, with
    dict_block_r=1 so matches sit on every tile's first/last element."""
    kc, vc = sdp.candidate_columns(enc)
    keys = np.asarray(jnp.stack(kc[:6], axis=1))      # tri-group candidates
    valid = np.asarray(jnp.stack(vc[:6], axis=1)) > 0
    tri = sorted(set(keys[valid].tolist()))
    assert len(tri) > sm.LANE                      # spans multiple tiles
    da = _arrays(tri=tri)
    ref = stemmer.stem_batch(enc, da)
    got = ops.extract_roots_fused(enc, da, residency="streamed",
                                  block_b=64, dict_block_r=1,
                                  num_buffers=2, interpret=True)
    _assert_parity(got, ref)
    # every word with a valid tri candidate found a root
    assert (np.asarray(ref[1]) == pyref.SRC_TRI).sum() == valid.any(1).sum()


def test_empty_visit_lists(enc):
    """Dictionary keys beyond any candidate key: zero tiles visited, and
    the kernel still writes clean no-hit outputs for every batch tile."""
    hi = 50 * 64 ** 3                              # above any packed letter
    da = _arrays(tri=[hi, hi + 1], quad=[hi + 2], bi=[hi + 3])
    st = sf.tile_visit_stats(enc, da, block_b=128, dict_block_r=2)
    assert st["visited"] == 0
    for nb in (1, 4):
        root, src = ops.extract_roots_fused(enc, da, residency="streamed",
                                            block_b=128, dict_block_r=2,
                                            num_buffers=nb, interpret=True)
        assert (np.asarray(src) == pyref.SRC_NONE).all()
        assert (np.asarray(root) == 0).all()


def test_num_buffers_validation(small, enc):
    with pytest.raises(ValueError, match="num_buffers"):
        ops.extract_roots_fused(enc, small, residency="streamed",
                                num_buffers=0, interpret=True)
    with pytest.raises(ValueError, match="num_buffers"):
        ops.extract_roots_fused(enc, small, residency="streamed",
                                num_buffers=5, interpret=True)


# ---------------------------------------------------------------------------
# residency budget scoping (the choose_residency infix fix)
# ---------------------------------------------------------------------------
def test_residency_budget_ignores_unloaded_bi(small, big):
    """A dictionary whose tri+quad fit the VMEM budget must stay resident
    for infix=False even when a huge bi table would blow it."""
    da = stemmer.RootDictArrays(tri=small.tri, quad=small.quad,
                                bi=big.quad)        # any big sorted table
    assert sf._loaded_keys(da, True) > sf.MAX_RESIDENT_KEYS
    assert sf._loaded_keys(da, False) <= sf.MAX_RESIDENT_KEYS
    assert sf.choose_residency(da, "auto", infix=True) == "streamed"
    assert sf.choose_residency(da, "auto", infix=False) == "resident"
    # and the resident launch itself accepts it with infix=False
    words, _, _ = corpus.build_corpus(n_words=96, seed=21)
    e = jnp.asarray(corpus.encode_corpus(words))
    ref = stemmer.stem_batch(e, da, infix=False)
    got = ops.extract_roots_fused(e, da, infix=False, residency="resident",
                                  interpret=True)
    _assert_parity(got, ref)
    with pytest.raises(ValueError, match="VMEM residency"):
        ops.extract_roots_fused(e, da, infix=True, residency="resident",
                                interpret=True)


# ---------------------------------------------------------------------------
# prebuilt tile stream (publish-time boundary tables) + serving knobs
# ---------------------------------------------------------------------------
def test_resolve_dict_prebuilds_tiles(big):
    h = stemmer.resolve_dict(big, dict_block_r=8)
    assert h.residency == "streamed" and h.tiles is not None
    assert h.tiles.dict_block_r == 8
    assert h.tiles.n_tiles == sum(h.tiles.counts)
    # boundary tables are per-tile first/last elements of the stream
    flat = np.asarray(h.tiles.stream).reshape(h.tiles.n_tiles, -1)
    np.testing.assert_array_equal(np.asarray(h.tiles.mins), flat[:, 0])
    np.testing.assert_array_equal(np.asarray(h.tiles.maxs), flat[:, -1])


def test_resolve_dict_upgrades_bare_handle(big):
    """Re-resolving an already-resolved handle with dict_block_r must
    build the tiles it lacks (publish must not silently skip the
    prebuild), and an already-matching handle passes through unchanged."""
    bare = stemmer.resolve_dict(big)                 # no tiles
    assert bare.tiles is None
    h = stemmer.resolve_dict(bare, dict_block_r=8)
    assert h.tiles is not None and h.tiles.dict_block_r == 8
    assert h.residency == bare.residency
    assert stemmer.resolve_dict(h, dict_block_r=8) is h   # no rebuild
    h2 = stemmer.resolve_dict(h, dict_block_r=4)          # height change
    assert h2.tiles.dict_block_r == 4


def test_prebuilt_tiles_bit_identical(big, enc):
    h = stemmer.resolve_dict(big, dict_block_r=8)
    ref = ops.extract_roots_fused(enc, big, residency="streamed",
                                  block_b=128, dict_block_r=8,
                                  interpret=True)
    got = ops.extract_roots_fused(enc, h, block_b=128, dict_block_r=8,
                                  interpret=True)
    _assert_parity(got, ref)


def test_mismatched_tile_height_rebuilds(big, enc):
    """A handle pinned at one dict_block_r still serves a call at another
    (the kernel rebuilds in-trace rather than mis-tiling)."""
    h = stemmer.resolve_dict(big, dict_block_r=4)
    ref = stemmer.stem_batch(enc, big)
    got = ops.extract_roots_fused(enc, h, block_b=128, dict_block_r=8,
                                  interpret=True)
    _assert_parity(got, ref)


def test_dict_store_publishes_tiles_and_keeps_trace(big, small, enc):
    from repro.serve import DictStore

    store = DictStore(big, dict_block_r=8)
    h = store.acquire().handle
    assert h.tiles is not None and h.residency == "streamed"
    ref = stemmer.stem_batch(enc, big)
    got = ops.extract_roots_fused(enc, h, block_b=128, interpret=True)
    _assert_parity(got, ref)
    # a shape-matched delta publish keeps the cached trace (tiles and all)
    before = sf.stem_fused_pallas._cache_size()
    k_new = 40 * 64 ** 3 + 7 * 64 ** 2 + 7 * 64
    k_old = int(np.asarray(big.tri)[0])
    store.publish_delta(insert={"tri": [k_new]}, remove={"tri": [k_old]})
    h2 = store.acquire().handle
    assert h2.tiles is not None
    ops.extract_roots_fused(enc, h2, block_b=128, interpret=True)
    assert sf.stem_fused_pallas._cache_size() == before
    # small dict resolves resident: no tile stream is built
    store_small = DictStore(small, dict_block_r=8)
    assert store_small.acquire().handle.tiles is None


def test_workload_pipeline_knobs_serve_parity(big):
    from repro.serve import DictStore, Engine, StemmerWorkload

    words, _, _ = corpus.build_corpus(n_words=150, seed=23)
    e = corpus.encode_corpus(words)
    store = DictStore(big, dict_block_r=4)
    eng = Engine(StemmerWorkload(store, block_b=64, dict_block_r=4,
                                 num_buffers=3, skip_index=True,
                                 max_inflight=2, interpret=True))
    rid = eng.submit(e)
    eng.run_until_drained()
    req = eng.result(rid)
    ref = stemmer.stem_batch(jnp.asarray(e), big)
    np.testing.assert_array_equal(req.roots, np.asarray(ref[0]))
    np.testing.assert_array_equal(req.sources, np.asarray(ref[1]))
