"""Crash-safety tests (DESIGN.md §12): the write-ahead request journal
and warm restart, DictStore catalog snapshots, the persistent-kernel
stall watchdog, and the graceful-degradation ladder.

The load-bearing invariant throughout: a recovered / degraded / salvaged
run returns bit-identical results to an uninterrupted one — the
megakernel's per-word output is independent of tile packing, so replay
through different coalescing boundaries, a watchdog's megabatch
re-dispatch, and every ladder rung all reproduce the same bytes.
"""
import os

import numpy as np
import pytest

from repro.core import corpus, stemmer
from repro.serve import (DegradationPolicy, DictSnapshotError, DictStore,
                         Engine, EventLog, FaultInjector, FaultPlan,
                         FaultSpec, Journal, JournalError, ServingMode,
                         StemmerWorkload, TextAnalysisWorkload,
                         build_ladder, payload_digest)
from repro.serve import journal as journal_mod

N_REQ, WPR = 6, 32


@pytest.fixture(scope="module")
def dict_and_words():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=N_REQ * WPR, seed=1)
    return arrays, corpus.encode_corpus(words)


@pytest.fixture(scope="module")
def baseline(dict_and_words):
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=2))
    rids = [eng.submit(enc[i * WPR:(i + 1) * WPR]) for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    return [np.array(eng.result(r).roots) for r in rids]


# ---------------------------------------------------------------------------
# the journal itself
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_unfinished(tmp_path):
    jp = tmp_path / "wal.jsonl"
    j = Journal(jp, fsync_every=2)
    pay = np.arange(32, dtype=np.int32).reshape(2, 16)
    j.admit(0, pay, deadline_s=1.5, dict_version=3, opts={"k": 1})
    j.admit(1, ["doc one", "doc two"])

    class _Req:
        rid = 0
        failure = None
        roots = np.ones((2, 4), np.int32)
        sources = np.zeros(2, np.int32)
    j.retire(_Req())
    j.close()

    records, dropped = Journal.read(jp)
    assert dropped == 0 and len(records) == 3
    a0, a1, r0 = records
    assert a0["kind"] == "admit" and a0["rid"] == 0
    assert a0["deadline_s"] == 1.5 and a0["dict_version"] == 3
    assert a0["opts"] == {"k": 1}
    got = journal_mod.decode_payload(a0["payload"])
    np.testing.assert_array_equal(got, pay)
    assert payload_digest(got) == a0["digest"]
    assert journal_mod.decode_payload(a1["payload"]) == ["doc one",
                                                         "doc two"]
    assert r0["kind"] == "retire" and r0["rid"] == 0
    assert isinstance(r0["digest"], str)
    # rid 1 has no retire: it is exactly what recovery owes
    unfinished = journal_mod.unfinished_admits(records)
    assert [r["rid"] for r in unfinished] == [1]


def test_journal_torn_tail_truncated(tmp_path):
    jp = tmp_path / "wal.jsonl"
    j = Journal(jp)
    for rid in range(4):
        j.admit(rid, [rid])
    j.close()
    good_size = os.path.getsize(jp)
    with open(jp, "ab") as f:       # a crash mid-append: half a record
        f.write(b"deadbeefdeadbeef {\"kind\": \"adm")
    records, dropped = Journal.read(jp)
    assert len(records) == 4 and dropped > 0
    assert os.path.getsize(jp) == good_size     # physically truncated
    # a corrupt record mid-file hides everything after it (WAL ordering
    # beyond a tear is unprovable)
    data = open(jp, "rb").read().splitlines(keepends=True)
    data[1] = b"0" * 16 + data[1][16:]
    open(jp, "wb").write(b"".join(data))
    records, dropped = Journal.read(jp, truncate=False)
    assert [r["rid"] for r in records] == [0] and dropped > 0


def test_payload_codec_rejects_unknown(tmp_path):
    with pytest.raises(TypeError, match="encode payload"):
        journal_mod.encode_payload({"not": "supported"})
    with pytest.raises(JournalError, match="codec"):
        journal_mod.decode_payload({"t": "mystery"})
    with pytest.raises(ValueError, match="fsync_every"):
        Journal(tmp_path / "j", fsync_every=0)


def test_fault_plan_rejects_unknown_sites_at_construction():
    with pytest.raises(ValueError, match="site"):
        FaultSpec("gpu")
    with pytest.raises(TypeError, match="FaultSpec"):
        FaultPlan(specs=(FaultSpec("dispatch"), "stall"))  # not a FaultSpec
    with pytest.raises(TypeError, match="FaultSpec"):
        FaultPlan(specs=(42,))
    with pytest.raises(ValueError, match="retired_tiles"):
        FaultSpec("stall", retired_tiles=-1)
    # the three new sites all construct + default to their only kind
    assert FaultSpec("stall").kind == "wedge"
    assert FaultSpec("device_loss").kind == "lost"
    assert FaultSpec("journal").kind == "tear"


# ---------------------------------------------------------------------------
# DictStore snapshots
# ---------------------------------------------------------------------------
def test_dict_snapshot_restore_roundtrip(dict_and_words, tmp_path):
    arrays, _ = dict_and_words
    store = DictStore(arrays, keep_history=True)
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    v1 = store.publish(grown)
    sp = tmp_path / "dict.npz"
    sha = store.snapshot(sp)
    assert isinstance(sha, str) and len(sha) == 16

    r = DictStore.restore(sp)
    assert r.version == v1 == 1
    for v in (0, 1):
        np.testing.assert_array_equal(
            np.asarray(r.get(v).arrays.tri),
            np.asarray(store.get(v).arrays.tri))
    # versions stay monotone across the restart (no renumbering)
    v2 = r.publish(corpus.grow_root_arrays(arrays, 1024, seed=9))
    assert v2 == 2


def test_dict_snapshot_tamper_detected(dict_and_words, tmp_path):
    arrays, _ = dict_and_words
    sp = tmp_path / "dict.npz"
    DictStore(arrays).snapshot(sp)
    with np.load(sp) as z:
        tables = {k: np.array(z[k]) for k in z.files}
    tables["v0_tri"][0] ^= 0x5A
    np.savez(sp, **tables)
    with pytest.raises(DictSnapshotError, match="content hash"):
        DictStore.restore(sp)


# ---------------------------------------------------------------------------
# warm restart: kill at every tick boundary
# ---------------------------------------------------------------------------
def test_kill_at_every_tick_boundary_bit_identical(dict_and_words,
                                                   baseline, tmp_path):
    """A journaled engine killed after k ticks, for EVERY k up to full
    drain, recovers with (pre-crash finished + replayed) outputs
    bit-identical to the uninterrupted run — including k=0 (nothing
    served) and the torn coalescing boundaries replay creates."""
    arrays, enc = dict_and_words
    for k in range(6):
        jp = tmp_path / f"wal_{k}.jsonl"
        eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                     max_inflight=2),
                     journal=Journal(jp, fsync_every=1))
        rids = [eng.submit(enc[i * WPR:(i + 1) * WPR])
                for i in range(N_REQ)]
        for _ in range(k):
            eng.step()
        done_before = {r: eng.result(r) for r in rids
                       if eng.result(r) is not None}
        # the process dies here: no close(), no sync — flushed appends
        # are all recovery gets
        eng2 = Engine.recover(jp, StemmerWorkload(DictStore(arrays),
                                                  block_b=32,
                                                  max_inflight=2))
        assert eng2.run_until_drained().drained
        assert sorted(eng2.recovery.replayed) == [
            r for r in rids if r not in done_before]
        for i, r in enumerate(rids):
            req = done_before.get(r) or eng2.result(r)
            assert req is not None and req.failure is None, (k, r)
            np.testing.assert_array_equal(req.roots, baseline[i],
                                          err_msg=f"kill at tick {k},"
                                                  f" rid {r}")
        # recovered rids are retired into the reopened journal: a second
        # recovery finds nothing left to replay
        eng3 = Engine.recover(jp, StemmerWorkload(DictStore(arrays),
                                                  block_b=32))
        assert eng3.recovery.replayed == []
        # and fresh submissions never reuse a journaled rid
        assert eng3._next_rid == N_REQ


def test_recovery_repins_admit_version_across_publish(dict_and_words,
                                                      baseline, tmp_path):
    """Requests admitted under dict v0 and recovered AFTER a v1 publish
    still serve under v0 (the journal pins the admitted lexicon), while
    post-restart submissions serve under v1."""
    arrays, enc = dict_and_words
    jp, sp = tmp_path / "wal.jsonl", tmp_path / "dict.npz"
    store = DictStore(arrays, keep_history=True)
    store.snapshot(sp)
    eng = Engine(StemmerWorkload(store, block_b=32),
                 journal=Journal(jp, fsync_every=1))
    rids = [eng.submit(enc[i * WPR:(i + 1) * WPR]) for i in range(2)]
    # crash before anything serves; the restarted store has moved on
    store2 = DictStore.restore(sp)
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    v1 = store2.publish(grown)
    eng2 = Engine.recover(jp, StemmerWorkload(store2, block_b=32))
    fresh = eng2.submit(enc[2 * WPR:3 * WPR])
    assert eng2.run_until_drained().drained
    for i, r in enumerate(rids):
        req = eng2.result(r)
        assert (req.dict_versions == 0).all()       # pinned at admit
        np.testing.assert_array_equal(req.roots, baseline[i])
    req = eng2.result(fresh)
    assert (req.dict_versions == v1).all()          # current lexicon
    want_r, _ = stemmer.stem_batch(req.words, grown)
    np.testing.assert_array_equal(req.roots, np.asarray(want_r))


def test_recovery_rejects_tampered_payload(dict_and_words, tmp_path):
    arrays, enc = dict_and_words
    jp = tmp_path / "wal.jsonl"
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32),
                 journal=Journal(jp, fsync_every=1))
    eng.submit(enc[:WPR])
    eng.journal.close()
    records, _ = Journal.read(jp)
    records[0]["digest"] = "0" * 16     # payload no longer matches
    j2 = Journal(tmp_path / "wal2.jsonl")
    j2._append(records[0])
    j2.close()
    with pytest.raises(JournalError, match="digest"):
        Engine.recover(tmp_path / "wal2.jsonl",
                       StemmerWorkload(DictStore(arrays), block_b=32))


def test_text_requests_replay_from_raw_documents(dict_and_words, tmp_path):
    """The journal stores text submissions as raw docs; replay re-runs
    the front end and reproduces identical analyses."""
    arrays, _ = dict_and_words
    docs = ["كتب الولد درسا", "ذهب الرجل الى السوق"]
    ref = Engine(TextAnalysisWorkload(DictStore(arrays), block_b=32,
                                      frontend="host"))
    ref_rids = [ref.submit([d]) for d in docs]
    assert ref.run_until_drained().drained
    want = [ref.result(r).analyses() for r in ref_rids]

    jp = tmp_path / "wal.jsonl"
    eng = Engine(TextAnalysisWorkload(DictStore(arrays), block_b=32,
                                      frontend="host"),
                 journal=Journal(jp, fsync_every=1))
    rids = [eng.submit([d]) for d in docs]
    # crash with both docs accepted, nothing served
    eng2 = Engine.recover(jp, TextAnalysisWorkload(DictStore(arrays),
                                                   block_b=32,
                                                   frontend="host"))
    assert eng2.run_until_drained().drained
    assert [eng2.result(r).analyses() for r in rids] == want


# ---------------------------------------------------------------------------
# the stall watchdog
# ---------------------------------------------------------------------------
def test_watchdog_requires_persistent(dict_and_words):
    arrays, _ = dict_and_words
    with pytest.raises(ValueError, match="persistent"):
        StemmerWorkload(DictStore(arrays), watchdog_s=0.1)
    with pytest.raises(ValueError, match="watchdog_s"):
        StemmerWorkload(DictStore(arrays), persistent=True, watchdog_s=0)


@pytest.mark.parametrize("retired_tiles", [0, 2])
def test_watchdog_abandons_wedged_launch(dict_and_words, baseline,
                                         retired_tiles):
    """A wedged persistent launch is abandoned at watchdog_s; the
    retired-prefix descriptors are salvaged (checksum-verified), the
    rest re-dispatch down the megabatch path, and zero requests are
    lost — bit-identical even at max_retries=0 (a stall charges no
    retry)."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(
        specs=(FaultSpec("stall", at=0, retired_tiles=retired_tiles),)))
    w = StemmerWorkload(DictStore(arrays), block_b=32, max_inflight=1,
                        persistent=True, megabatch_tiles=4,
                        watchdog_s=0.05, max_retries=0, injector=inj)
    eng = Engine(w)
    rids = [eng.submit(enc[i * WPR:(i + 1) * WPR]) for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    assert w.watchdog_stalls == 1 and w.retries_total == 0
    ev, = [e for e in eng.events() if e.kind == "watchdog_stall"]
    assert ev.data["salvaged_words"] == retired_tiles * 32
    assert ev.data["redispatched_words"] > 0
    for i, r in enumerate(rids):
        req = eng.result(r)
        assert req.failure is None
        np.testing.assert_array_equal(req.roots, baseline[i])


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------
def test_build_ladder_rungs():
    rungs = build_ladder(persistent=True, megabatch_tiles=4,
                         data_devices=4, resident_dict=True)
    labels = [r.label for r in rungs]
    assert labels == ["persistent", "megabatch x4", "per-tile",
                      "streamed-dict", "devices-2", "devices-1"]
    assert rungs[0].persistent and not rungs[1].persistent
    assert rungs[-1].data_devices == 1
    # minimal config: the ladder still has a rung to stand on
    assert [r.label for r in build_ladder(resident_dict=False)] == [
        "per-tile"]


class _FakeWorkload:
    def __init__(self, data_devices=1):
        self.persistent = True
        self.megabatch_tiles = 2
        self.data_devices = data_devices
        self.retries_total = 0
        self.checksum_failures = 0
        self.timeouts = 0
        self.watchdog_stalls = 0
        self.device_losses = 0
        self.modes: list[ServingMode] = []

    def request_mode(self, mode):
        self.modes.append(mode)


class _FakeEngine:
    def __init__(self):
        self.queue = []


def _policy(w, **kw):
    p = DegradationPolicy(rungs=build_ladder(
        persistent=w.persistent, megabatch_tiles=w.megabatch_tiles,
        data_devices=w.data_devices, resident_dict=False), **kw)
    p.attach(w, EventLog())
    return p


def test_policy_hysteresis_down_and_up():
    w, eng = _FakeWorkload(), _FakeEngine()
    p = _policy(w, down_after=2, up_after=3)
    w.retries_total += 1
    p.observe(eng)                       # 1 unhealthy: no shift yet
    assert p.mode.label == "persistent" and not w.modes
    w.retries_total += 1
    p.observe(eng)                       # 2 consecutive: downshift
    assert p.mode.label == "megabatch x2"
    assert w.modes[-1].label == "megabatch x2"
    for _ in range(2):
        p.observe(eng)                   # healthy, but under up_after
    assert p.mode.label == "megabatch x2"
    p.observe(eng)                       # 3rd healthy: upshift
    assert p.mode.label == "persistent"
    assert [t[2] for t in p.transitions] == ["faults", "healthy"]
    # a fault burst resets the healthy streak (no oscillation)
    w.checksum_failures += 1
    p.observe(eng)
    assert p._healthy == 0


def test_policy_queue_pressure_downshifts():
    w, eng = _FakeWorkload(), _FakeEngine()
    p = _policy(w, queue_high=4, down_after=2)
    eng.queue = list(range(5))
    p.observe(eng)
    p.observe(eng)
    assert p.mode.label == "megabatch x2"
    assert p.transitions[-1][2] == "queue"


def test_policy_device_loss_downshifts_and_caps():
    w, eng = _FakeWorkload(data_devices=4), _FakeEngine()
    p = _policy(w, down_after=2, up_after=1)
    assert [r.label for r in p.rungs] == [
        "persistent", "megabatch x2", "per-tile", "devices-2", "devices-1"]
    w.device_losses += 1
    p.observe(eng)                       # immediate, no hysteresis
    assert p.mode.label == "devices-2"
    assert p.transitions[-1][2] == "device_loss"
    for _ in range(8):
        p.observe(eng)                   # healthy forever...
    assert p.mode.data_devices <= 2      # ...but never past the cap
    w.device_losses += 1
    p.observe(eng)                       # second loss: down to 1
    assert p.mode.label == "devices-1"


def test_policy_validation():
    with pytest.raises(ValueError, match="queue_high"):
        DegradationPolicy(queue_high=0)
    with pytest.raises(ValueError, match="down_after"):
        DegradationPolicy(down_after=0)
    with pytest.raises(ValueError, match="request_mode"):
        DegradationPolicy().attach(object(), EventLog())


def test_ladder_transition_serves_bit_identical(dict_and_words, baseline):
    """A mid-stream downshift (persistent -> megabatch -> per-tile ->
    streamed-dict) re-chunks waiting work to the new launch width and
    keeps every result bit-identical."""
    arrays, enc = dict_and_words
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("stall", count=3),)))
    w = StemmerWorkload(DictStore(arrays), block_b=32, max_inflight=1,
                        persistent=True, megabatch_tiles=2,
                        watchdog_s=0.02, injector=inj)
    pol = DegradationPolicy(down_after=1, up_after=100)
    eng = Engine(w, policy=pol)
    rids = [eng.submit(enc[i * WPR:(i + 1) * WPR]) for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    assert pol.transitions and pol.transitions[0][0] == "persistent"
    assert not w.persistent              # off the wedged rung
    kinds = {e.kind for e in eng.events()}
    assert "degrade" in kinds and "watchdog_stall" in kinds
    for i, r in enumerate(rids):
        req = eng.result(r)
        assert req.failure is None
        np.testing.assert_array_equal(req.roots, baseline[i])


# ---------------------------------------------------------------------------
# the structured event stream
# ---------------------------------------------------------------------------
def test_events_surface_failures_and_recovery(dict_and_words, tmp_path):
    arrays, enc = dict_and_words
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32),
                 queue_cap=1, on_full="shed",
                 journal=Journal(tmp_path / "wal.jsonl", fsync_every=1))
    eng.submit(enc[:WPR])
    eng.submit(enc[:WPR])                # shed: terminal, never journaled
    fails = [e for e in eng.events() if e.kind == "failure"]
    assert len(fails) == 1 and fails[0].data["code"] == "shed"
    assert eng.run_until_drained().drained
    eng2 = Engine.recover(tmp_path / "wal.jsonl",
                          StemmerWorkload(DictStore(arrays), block_b=32))
    rec, = [e for e in eng2.events() if e.kind == "recovered"]
    # both rids count as retired: the served one AND the shed one (shed
    # is terminal — retired without ever being admitted)
    assert rec.data["replayed"] == 0 and rec.data["already_retired"] == 2
    # events(drain=True) hands the stream over exactly once
    assert eng2.events(drain=True) and not eng2.events()


# ---------------------------------------------------------------------------
# launcher flag cross-validation (before any engine is constructed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("argv", [
    ["--workload", "stemmer", "--watchdog-ms", "50"],        # no --persistent
    ["--workload", "lm", "--watchdog-ms", "50"],
    ["--workload", "lm", "--degrade", "on"],
    ["--workload", "stemmer", "--watchdog-ms", "-1", "--persistent"],
])
def test_serve_launcher_rejects_bad_flag_combos(argv, monkeypatch):
    from repro.launch import serve as serve_mod

    monkeypatch.setattr("sys.argv", ["serve.py"] + argv)
    with pytest.raises(SystemExit) as exc:
        serve_mod.main()
    assert exc.value.code == 2          # argparse .error(), pre-engine
