"""Sharding resolver unit tests (no multi-device requirements)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import sharding
from repro.models import model as model_mod
from repro.models import params as pm


class FakeMesh:
    """Duck-typed mesh: resolve() only reads axis_names + devices.shape."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as np

        self.devices = np.empty(tuple(sizes.values()))


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_dims_shard():
    spec = sharding.resolve(("fsdp", "model"), (4096, 14336), MESH1)
    assert spec == P("data", "model")


def test_indivisible_head_dim_replicates():
    # 25 heads (hymba) cannot shard on model=16
    spec = sharding.resolve(("fsdp", "heads", None), (1600, 25, 64), MESH1)
    assert spec == P("data", None, None)


def test_batch_prefix_backoff():
    # batch=16 on (data=16, pod=2): full group 32 doesn't divide, prefix does
    spec = sharding.resolve(("batch", None), (16, 128), MESH2)
    assert spec == P("data", None)


def test_batch_one_replicates():
    spec = sharding.resolve(("batch", None), (1, 128), MESH2)
    assert spec == P(None, None)


def test_axis_uniqueness():
    # experts takes model; a later "model" dim must not reuse it
    spec = sharding.resolve(("experts", "fsdp", "model"), (128, 2048, 1536), MESH1)
    assert spec == P("model", "data", None)


def test_multi_pod_fsdp_uses_both_axes():
    spec = sharding.resolve(("fsdp", "model"), (4096, 14336), MESH2)
    assert spec == P(("data", "pod"), "model")


def test_kv_seq_on_model():
    spec = sharding.resolve(("layers", "batch", "kv_seq", None, None),
                            (32, 128, 32768, 8, 128), MESH1)
    assert spec == P(None, "data", "model", None, None)


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_param_specs_resolve_for_all_archs(arch):
    """Every parameter of every full config resolves on both meshes."""
    cfg = configs.get_config(arch)
    spec = model_mod.model_spec(cfg)
    flat = jax.tree.leaves(spec, is_leaf=pm.is_spec)
    for mesh in (MESH1, MESH2):
        for s in flat:
            p = sharding.resolve(s.axes, s.shape, mesh)
            # every sharded dim must divide
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for dim, entry in zip(s.shape, p):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                prod = 1
                for a in axes:
                    prod *= sizes[a]
                assert dim % prod == 0, (arch, s.shape, p)


def test_cache_axes_structure_matches_all_archs():
    """cache_logical_axes must stay in lock-step with init_caches."""
    for arch in sorted(configs.ARCHS):
        cfg = configs.smoke_config(configs.get_config(arch))
        shapes = jax.eval_shape(lambda c=cfg: model_mod.init_caches(c, 2, 16))
        axes = model_mod.cache_logical_axes(cfg)
        is_axes = lambda x: isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x)
        n_shapes = len(jax.tree.leaves(shapes))
        n_axes = len(jax.tree.flatten(axes, is_leaf=is_axes)[0])
        assert n_shapes == n_axes, arch
