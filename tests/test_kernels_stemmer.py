"""Pallas kernel tests: interpret-mode vs pure-jnp oracles, shape sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import corpus, pyref, stemmer
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels import stem_datapath as sdp
from repro.kernels import stem_match as sm


@pytest.fixture(scope="module")
def dicts():
    d = corpus.build_dictionary(n_tri=800, n_quad=100, seed=7)
    return d, stemmer.RootDictArrays.from_rootdict(d)


# ---------------------------------------------------------------------------
# dict_match kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 5, 128, 300, 1024])
@pytest.mark.parametrize("r", [1, 64, 500, 2048])
def test_dict_match_shapes(n, r):
    rng = np.random.default_rng(n * 1000 + r)
    dict_keys = jnp.asarray(np.unique(rng.integers(0, 2**24, size=r)).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 2**24, size=n).astype(np.int32))
    got = sm.dict_match_pallas(keys, dict_keys, interpret=True)
    want = kref.dict_match_ref(keys, dict_keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_n,block_r", [(1, 1), (2, 8), (4, 2)])
def test_dict_match_block_shapes(block_n, block_r):
    rng = np.random.default_rng(0)
    dict_keys = jnp.asarray(np.sort(rng.integers(0, 2**24, 700)).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 2**24, 513).astype(np.int32))
    # force hits
    keys = keys.at[:100].set(dict_keys[:100])
    got = sm.dict_match_pallas(
        keys, dict_keys, block_n=block_n, block_r=block_r, interpret=True
    )
    want = kref.dict_match_ref(keys, dict_keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 260),
    r=st.integers(1, 600),
    seed=st.integers(0, 2**16),
)
def test_dict_match_property(n, r, seed):
    rng = np.random.default_rng(seed)
    dict_keys = jnp.asarray(np.unique(rng.integers(0, 2**24, r)).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 2**24, n).astype(np.int32))
    got = sm.dict_match_pallas(keys, dict_keys, interpret=True)
    want = kref.dict_match_ref(keys, dict_keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# stem_datapath kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b", [1, 7, 64, 256, 500])
def test_datapath_matches_ref(b):
    words, _, _ = corpus.build_corpus(n_words=b, seed=b)
    enc = jnp.asarray(corpus.encode_corpus(words))
    keys, valid = sdp.stem_datapath_pallas(enc, block_b=64, interpret=True)
    rkeys, rvalid = kref.stem_datapath_ref(enc)
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(rvalid))
    # keys only compared where valid (invalid slots may hold garbage chars)
    mask = np.asarray(rvalid) > 0
    np.testing.assert_array_equal(np.asarray(keys)[mask], np.asarray(rkeys)[mask])


@pytest.mark.parametrize("block_b", [8, 32, 256])
def test_datapath_block_sweep(block_b):
    words, _, _ = corpus.build_corpus(n_words=100, seed=1)
    enc = jnp.asarray(corpus.encode_corpus(words))
    keys, valid = sdp.stem_datapath_pallas(enc, block_b=block_b, interpret=True)
    rkeys, rvalid = kref.stem_datapath_ref(enc)
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(rvalid))
    mask = np.asarray(rvalid) > 0
    np.testing.assert_array_equal(np.asarray(keys)[mask], np.asarray(rkeys)[mask])


# ---------------------------------------------------------------------------
# fused kernel pipeline == core stemmer == pyref
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("infix", [True, False])
def test_fused_pipeline_matches_core(dicts, infix):
    d, da = dicts
    words, _, _ = corpus.build_corpus(n_words=300, seed=11)
    enc = jnp.asarray(corpus.encode_corpus(words))
    r1, s1 = ops.extract_roots_fused(enc, da, infix=infix, interpret=True)
    r2, s2 = stemmer.stem_batch(enc, da, infix=infix)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_fused_pipeline_matches_pyref(dicts):
    d, da = dicts
    words = ["أفاستسقيناكموها", "سيلعبون", "قال", "كاتب", "درس", "فتزحزحت"]
    enc = jnp.asarray(corpus.encode_corpus(words))
    roots, srcs = ops.extract_roots_fused(enc, da, interpret=True)
    for i, w in enumerate(words):
        want_root, want_src = pyref.extract_root(enc[i], d)
        got = tuple(int(c) for c in np.asarray(roots)[i] if c)
        assert got == want_root, w
        assert int(srcs[i]) == want_src, w


def test_pallas_backend_in_core_stemmer(dicts):
    """'pallas' backend is selectable from the core public API."""
    _, da = dicts
    words, _, _ = corpus.build_corpus(n_words=128, seed=13)
    enc = jnp.asarray(corpus.encode_corpus(words))
    r1, s1 = stemmer.stem_batch(enc, da, backend="pallas")
    r2, s2 = stemmer.stem_batch(enc, da, backend="sorted")
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
