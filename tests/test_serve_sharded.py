"""Sharded serving-path tests (4 forced host devices via subprocess —
the main pytest session must keep the default single device).

Covers dist.shard_batch parity (full and ragged super-tiles) against
stem_batch / the single-device megakernel, StemmerWorkload
``data_devices=4`` serving through the dispatch/retire ring, a
dictionary hot swap landing while sharded super-tiles are in flight,
a journaled 4-device kill/warm-restart, and an injected device loss
downshifting the degradation ladder onto a smaller mesh.
CI runs this file as its forced-4-device step.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.dist import mesh_axis_size

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import corpus, stemmer
    from repro.dist import shard_batch
    from repro.kernels import ops
    from repro.launch import mesh as mesh_mod
    from repro.serve import DictStore, Engine, StemmerWorkload

    assert len(jax.devices()) == 4
    mesh = mesh_mod.make_data_mesh(4)
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=200, seed=1)
    enc = corpus.encode_corpus(words)

    # --- shard_batch parity: full super-tile and ragged batches -------
    for n in (128, 100, 7):          # 4*32 exact | ragged | < one tile
        got_r, got_s = shard_batch(jnp.asarray(enc[:n]), arrays, mesh,
                                   block_b=32, interpret=True)
        want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:n]), arrays)
        np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want_r))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
        # and identical to the single-device megakernel launch
        one_r, one_s = ops.extract_roots_fused(jnp.asarray(enc[:n]), arrays,
                                               block_b=32)
        np.testing.assert_array_equal(np.asarray(got_r), np.asarray(one_r))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(one_s))
    print("SHARD_BATCH_PARITY_OK")

    # --- streamed pipeline knobs across the mesh: a resolved handle with
    # publish-time tile/boundary tables, DMA ladder depth, skip on/off --
    grown = corpus.grow_root_arrays(arrays, 100_000, seed=3)
    handle = stemmer.resolve_dict(grown, dict_block_r=8)
    assert handle.residency == "streamed" and handle.tiles is not None
    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:128]), grown)
    for nb, sk in ((1, True), (2, True), (2, False)):
        got_r, got_s = shard_batch(jnp.asarray(enc[:128]), handle, mesh,
                                   block_b=32, num_buffers=nb,
                                   skip_index=sk, interpret=True)
        np.testing.assert_array_equal(np.asarray(got_r), np.asarray(want_r))
        np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    print("SHARD_PIPELINE_KNOBS_OK")

    # --- sharded serving: super-tile coalescing through the ring ------
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16, data_devices=4,
                                 max_inflight=2))
    sizes = (37, 64, 5, 50)          # 156 words, super_b=64 -> 3 launches
    off, rids = 0, []
    for n in sizes:
        rids.append(eng.submit(enc[off:off + n])); off += n
    rep = eng.run_until_drained()
    assert rep.drained
    assert eng.workload.super_b == 64
    assert eng.workload.ticks_launched == -(-sum(sizes) // 64)
    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:sum(sizes)]),
                                        arrays)
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    got_s = np.concatenate([eng.result(r).sources for r in rids])
    np.testing.assert_array_equal(got_r, np.asarray(want_r))
    np.testing.assert_array_equal(got_s, np.asarray(want_s))
    assert all((eng.result(r).dict_versions == 0).all() for r in rids)
    print("SHARD_SERVE_PARITY_OK")

    # --- hot swap landing while sharded super-tiles are in flight -----
    store = DictStore(arrays)
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    eng = Engine(StemmerWorkload(store, block_b=16, data_devices=4,
                                 max_inflight=2))
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(6)]
    eng.step()                       # 2 super-tiles (128 words) in flight
    assert eng.workload.ticks_launched == 2
    v1 = store.publish(grown)
    rep = eng.run_until_drained()
    assert rep.drained and v1 == 1
    versions = np.concatenate([eng.result(r).dict_versions for r in rids])
    np.testing.assert_array_equal(versions[:128], 0)   # pinned at dispatch
    np.testing.assert_array_equal(versions[128:], 1)
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    for v, sl in ((0, slice(0, 128)), (1, slice(128, 192))):
        want_r, _ = stemmer.stem_batch(jnp.asarray(enc[sl]),
                                       store.get(v).arrays)
        np.testing.assert_array_equal(got_r[sl], np.asarray(want_r))
    print("SHARD_SWAP_OK")

    # --- sharded megabatch: one launch spans megabatch_tiles super-tiles
    # across the mesh, bit-identical to the per-super-tile path ---------
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16, data_devices=4,
                                 megabatch_tiles=2, max_inflight=1))
    sizes = (37, 64, 5, 50)          # 156 words, launch_b=128 -> 2 launches
    off, rids = 0, []
    for n in sizes:
        rids.append(eng.submit(enc[off:off + n])); off += n
    rep = eng.run_until_drained()
    assert rep.drained
    assert eng.workload.launch_b == 128
    assert eng.workload.ticks_launched == 2   # vs 3 per-super-tile above
    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:sum(sizes)]),
                                        arrays)
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    got_s = np.concatenate([eng.result(r).sources for r in rids])
    np.testing.assert_array_equal(got_r, np.asarray(want_r))
    np.testing.assert_array_equal(got_s, np.asarray(want_s))
    print("SHARD_MEGABATCH_OK")

    # --- text workload over the mesh: raw documents through the sharded
    # super-tile ring, bit-identical to the host pipeline --------------
    from repro.core import textnorm as tn
    from repro.launch.serve import build_documents
    from repro.serve import TextAnalysisWorkload

    store = DictStore(arrays)
    eng = Engine(TextAnalysisWorkload(store, block_b=16, data_devices=4,
                                      char_block=256, megabatch_tiles=2,
                                      max_inflight=2))
    docs = build_documents(4, 40, seed=2)
    rids = [eng.submit([d]) for d in docs]
    rep = eng.run_until_drained()
    assert rep.drained
    for rid, doc in zip(rids, docs):
        req = eng.result(rid)
        want_w, want_spans = tn.analyze_text_py(doc)
        np.testing.assert_array_equal(req.words, want_w)
        np.testing.assert_array_equal(req.spans, want_spans)
        want_r, want_s = stemmer.stem_batch(jnp.asarray(want_w), arrays)
        np.testing.assert_array_equal(req.roots, np.asarray(want_r))
        np.testing.assert_array_equal(req.sources, np.asarray(want_s))
    print("TEXT_SHARD_OK")

    # --- sharded retry parity: an injected launch failure on the first
    # sharded dispatch is retried and the drain stays bit-identical ----
    from repro.serve import FaultInjector, FaultPlan, FaultSpec

    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=0),)))
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=16, data_devices=4,
                                 max_inflight=2, injector=inj))
    sizes = (37, 64, 5, 50)
    off, rids = 0, []
    for n in sizes:
        rids.append(eng.submit(enc[off:off + n])); off += n
    rep = eng.run_until_drained()
    assert rep.drained
    assert eng.workload.retries_total == 1
    assert inj.fired == [("dispatch", "fail", 0)]
    want_r, want_s = stemmer.stem_batch(jnp.asarray(enc[:sum(sizes)]),
                                        arrays)
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    got_s = np.concatenate([eng.result(r).sources for r in rids])
    np.testing.assert_array_equal(got_r, np.asarray(want_r))
    np.testing.assert_array_equal(got_s, np.asarray(want_s))
    assert all(eng.result(r).failure is None for r in rids)
    print("SHARD_RETRY_OK")

    # --- 4-device warm restart: a journaled sharded engine killed after
    # one super-tile tick recovers from the WAL and the merged
    # (pre-crash + replayed) outputs are bit-identical -----------------
    import tempfile
    from repro.serve import DegradationPolicy, Journal

    jp = os.path.join(tempfile.mkdtemp(), "wal.jsonl")
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=16,
                                 data_devices=4, max_inflight=1),
                 journal=Journal(jp, fsync_every=1))
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(6)]
    eng.step()                       # one sharded tick, then "crash"
    done = {r: eng.result(r) for r in rids if eng.result(r) is not None}
    eng2 = Engine.recover(jp, StemmerWorkload(DictStore(arrays),
                                              block_b=16, data_devices=4,
                                              max_inflight=1))
    assert eng2.run_until_drained().drained
    assert set(eng2.recovery.replayed) == {r for r in rids
                                           if r not in done}
    merged = np.concatenate([(done.get(r) or eng2.result(r)).roots
                             for r in rids])
    want_r, _ = stemmer.stem_batch(jnp.asarray(enc[:192]), arrays)
    np.testing.assert_array_equal(merged, np.asarray(want_r))
    print("SHARD_RECOVER_OK")

    # --- device loss under the ladder: an injected DeviceLost on the
    # first sharded launch downshifts to fewer data devices (capped —
    # a lost device does not come back) and the drain, re-served on the
    # smaller mesh, stays bit-identical --------------------------------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("device_loss", at=0),)))
    pol = DegradationPolicy(down_after=1)
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=16,
                                 data_devices=4, max_inflight=1,
                                 injector=inj), policy=pol)
    rids = [eng.submit(enc[i * 32:(i + 1) * 32]) for i in range(6)]
    assert eng.run_until_drained().drained
    eng.step()                       # a requested mode lands at an
    assert eng.workload.device_losses == 1      # empty-ring tick
    assert any(t[2] == "device_loss" for t in pol.transitions)
    assert eng.workload.data_devices < 4
    got_r = np.concatenate([eng.result(r).roots for r in rids])
    np.testing.assert_array_equal(got_r, np.asarray(want_r))
    assert all(eng.result(r).failure is None for r in rids)
    print("SHARD_DEVICE_LOSS_OK")
""")


def test_sharded_serve_four_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    for marker in ("SHARD_BATCH_PARITY_OK", "SHARD_PIPELINE_KNOBS_OK",
                   "SHARD_SERVE_PARITY_OK", "SHARD_SWAP_OK",
                   "SHARD_MEGABATCH_OK", "TEXT_SHARD_OK",
                   "SHARD_RETRY_OK", "SHARD_RECOVER_OK",
                   "SHARD_DEVICE_LOSS_OK"):
        assert marker in proc.stdout, proc.stderr[-2000:]


# ---------------------------------------------------------------------------
# in-process validation (no multi-device requirements)
# ---------------------------------------------------------------------------
class FakeMesh:
    def __init__(self, sizes):
        import numpy as np

        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


def test_mesh_axis_size_resolves_and_rejects():
    mesh = FakeMesh({"data": 4, "model": 2})
    assert mesh_axis_size(mesh, "data") == 4
    with pytest.raises(ValueError, match="no axis"):
        mesh_axis_size(mesh, "stage")


def test_workload_rejects_unavailable_devices():
    """data_devices beyond the backend's device count fails at
    construction, not at first launch (main session has one device)."""
    import jax

    from repro.core import corpus, stemmer
    from repro.serve import DictStore, StemmerWorkload

    d = corpus.build_dictionary(n_tri=50, n_quad=10, seed=0)
    store = DictStore(stemmer.RootDictArrays.from_rootdict(d))
    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="devices"):
        StemmerWorkload(store, data_devices=too_many)
    with pytest.raises(ValueError, match="max_inflight"):
        StemmerWorkload(store, max_inflight=0)
    with pytest.raises(ValueError, match="data_devices"):
        StemmerWorkload(store, data_devices=0)
