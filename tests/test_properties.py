"""Hypothesis property tests on system invariants.

(The hypothesis-free exhaustive pack_keys/unpack_keys grid test lives in
test_stemmer.py so it keeps coverage on hosts without hypothesis — this
whole module skips there.)
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import alphabet as ab
from repro.core import corpus, pyref, stemmer

ARABIC_LETTERS = [chr(cp) for cp, c in ab.CP_TO_CODE.items() if c]


@st.composite
def arabic_words(draw, min_size=1, max_size=15):
    n = draw(st.integers(min_size, max_size))
    return "".join(draw(st.sampled_from(ARABIC_LETTERS)) for _ in range(n))


@settings(max_examples=60, deadline=None)
@given(arabic_words())
def test_encode_decode_roundtrip_property(word):
    enc = ab.encode_word(word)
    assert ab.decode_word(enc) == ab.normalise(word)[:15]
    assert enc.shape == (ab.MAXLEN,)
    assert (enc >= 0).all() and (enc < ab.N_CODES).all()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=0, max_size=4))
def test_pack_key_bijective_property(codes):
    k = ab.pack_key(codes)
    assert 0 <= k < 2**24
    assert ab.unpack_key(k) == (list(codes) + [0] * 4)[:4]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.lists(st.integers(0, 63), min_size=4, max_size=4),
                min_size=1, max_size=12))
def test_pack_unpack_keys_roundtrip_property(rows):
    """The batched JAX packers round-trip every valid 6-bit char code
    (previously only exercised indirectly through the parity suites), and
    agree with the scalar alphabet.pack_key reference."""
    import jax.numpy as jnp

    from repro.kernels import ops

    codes = np.asarray(rows, np.int32)                 # [n, 4], codes 0..63
    keys = np.asarray(stemmer.pack_keys(jnp.asarray(codes)))
    assert keys.shape == (codes.shape[0],)
    assert ((keys >= 0) & (keys < 2**24)).all()
    np.testing.assert_array_equal(
        np.asarray(ops.unpack_keys(jnp.asarray(keys))), codes)
    for row, key in zip(rows, keys.tolist()):
        assert ab.pack_key(row) == key


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_root_fixed_point_property(seed):
    """Stemming a trilateral dictionary root returns the root itself:
    the (no-prefix, no-suffix) candidate is first in priority order."""
    d = corpus.build_dictionary(n_tri=400, n_quad=50, seed=3)
    tris = sorted(d.tri)
    root = tris[seed % len(tris)]
    got, src = pyref.extract_root(list(root), d)
    assert got == root
    assert src == pyref.SRC_TRI


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 48))
def test_jax_pyref_agree_on_random_words(seed, n):
    """The vectorised implementation equals the oracle on arbitrary
    (not just conjugated) letter strings — garbage in, same answer out."""
    rng = np.random.default_rng(seed)
    d = corpus.build_dictionary(n_tri=300, n_quad=40, seed=9)
    da = stemmer.RootDictArrays.from_rootdict(d)
    lens = rng.integers(1, 15, size=n)
    words = ["".join(rng.choice(ARABIC_LETTERS, ln)) for ln in lens]
    enc = corpus.encode_corpus(words)
    roots_jax, src_jax = stemmer.stem_batch(enc, da)
    roots_jax, src_jax = np.asarray(roots_jax), np.asarray(src_jax)
    for i in range(n):
        want_root, want_src = pyref.extract_root(enc[i], d)
        got = tuple(int(c) for c in roots_jax[i] if c)
        assert got == want_root, words[i]
        assert int(src_jax[i]) == want_src, words[i]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_source_tags_consistent_with_dict_membership(seed):
    """Whatever source the stemmer reports, the returned root must be a
    member of the dictionary the tag claims it came from."""
    rng = np.random.default_rng(seed)
    d = corpus.build_dictionary(n_tri=300, n_quad=40, seed=11)
    words, _, _ = corpus.build_corpus(n_words=40, seed=seed % 1000)
    for w in words:
        root, src = pyref.stem_word(w, d, extended=True)
        enc = tuple(int(c) for c in ab.encode_word(root) if c)
        if src in (pyref.SRC_TRI, pyref.SRC_RESTORED, pyref.SRC_DEINFIX_TRI,
                   pyref.SRC_EXT_DEFECTIVE, pyref.SRC_EXT_HOLLOW_Y):
            assert enc in d.tri
        elif src == pyref.SRC_QUAD:
            assert enc in d.quad
        elif src == pyref.SRC_DEINFIX_BI:
            assert enc in d.bi
        else:
            assert src == pyref.SRC_NONE and root == ""
