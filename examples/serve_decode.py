"""Serve a small model with batched, continuously-batched requests.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as model_mod
from repro.models import params as pm
from repro.serve.engine import ServeEngine


def main():
    cfg = configs.smoke_config(configs.get_config("llama3-8b"))
    params = pm.init_params(model_mod.model_spec(cfg), jax.random.key(7))
    eng = ServeEngine(cfg, params, max_batch=3, cache_len=128)

    rng = np.random.default_rng(1)
    t0 = time.time()
    rids = [eng.submit(rng.integers(0, cfg.vocab, 6), max_new=6)
            for _ in range(7)]  # 7 requests share 3 slots
    rep = eng.run_until_drained()
    dt = time.time() - t0

    toks = sum(len(eng.result(r).tokens_out) for r in rids)
    print(f"{len(rids)} requests, {toks} tokens, {rep.ticks} ticks, "
          f"{toks/dt:.1f} tok/s")
    for rid in rids:
        print(f"  req {rid}: {eng.result(rid).tokens_out}")


if __name__ == "__main__":
    main()
