"""Stream raw Quranic text through the text-analysis serving path.

A Surat Al-Ankabut excerpt (29:1-3, fully vocalised — diacritics,
alef-wasla, madda, the works) plus synthesised cliticised corpus
documents go through Engine + TextAnalysisWorkload: the Pallas text
front end segments and normalises the raw codepoints into word tiles,
the stemmer megakernel serves them through the dispatch/retire ring,
and every per-token (root, source, byte_span) is verified bit-identical
to the host pipeline (textnorm.analyze_text_py -> stem_batch) — the
script exits non-zero on any mismatch, so CI runs it as a smoke test.

  PYTHONPATH=src python examples/serve_text.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import corpus, stemmer
from repro.core import textnorm as tn
from repro.serve import DictStore, Engine, TextAnalysisWorkload

# Surat Al-Ankabut 29:1-3 (vocalised Quranic orthography)
ANKABUT = (
    "الم "
    "أَحَسِبَ النَّاسُ أَن يُتْرَكُوا أَن يَقُولُوا آمَنَّا "
    "وَهُمْ لَا يُفْتَنُونَ "
    "وَلَقَدْ فَتَنَّا الَّذِينَ مِن قَبْلِهِمْ "
    "فَلَيَعْلَمَنَّ اللَّهُ الَّذِينَ صَدَقُوا "
    "وَلَيَعْلَمَنَّ الْكَاذِبِينَ"
)

BLOCK_B = 64


def main():
    d = corpus.build_dictionary(n_tri=800, n_quad=100, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    store = DictStore(arrays)
    eng = Engine(TextAnalysisWorkload(store, block_b=BLOCK_B,
                                      char_block=512, megabatch_tiles=2))

    # the excerpt + cliticised corpus documents (strip path exercised)
    words, _, _ = corpus.build_corpus(n_words=120, seed=1)
    pro = ("وال", "ب", "ف", "لل", "ك", "")
    docs = [ANKABUT] + [
        " ".join(pro[j % len(pro)] + w
                 for j, w in enumerate(words[i * 30:(i + 1) * 30]))
        for i in range(4)
    ]
    n_bytes = sum(len(doc.encode("utf-8")) for doc in docs)

    t0 = time.time()
    rids = [eng.submit(doc) for doc in docs]
    rep = eng.run_until_drained()
    dt = time.time() - t0

    n_words = sum(eng.result(r).n_words for r in rids)
    print(f"served {len(docs)} documents / {n_bytes} bytes / {n_words}"
          f" words in {dt:.2f}s ({n_bytes / dt:.0f} B/s,"
          f" {n_words / dt:.1f} Wps, {rep.ticks} ticks)")

    # bit-exact parity: every token vs the host pipeline + stem_batch
    checked = 0
    for rid, doc in zip(rids, docs):
        req = eng.result(rid)
        assert req.done and len(req.docs) == 1
        want_w, want_spans = tn.analyze_text_py(doc)
        assert req.n_words == want_w.shape[0], (
            f"req {rid}: {req.n_words} tokens vs host {want_w.shape[0]}")
        np.testing.assert_array_equal(req.words, want_w)
        np.testing.assert_array_equal(req.spans, want_spans)
        want_r, want_s = stemmer.stem_batch(jnp.asarray(want_w), arrays)
        np.testing.assert_array_equal(req.roots, np.asarray(want_r))
        np.testing.assert_array_equal(req.sources, np.asarray(want_s))
        # spans must round-trip through the document bytes
        raw = doc.encode("utf-8")
        for (b0, b1) in req.spans:
            assert 0 <= b0 < b1 <= len(raw)
            raw[b0:b1].decode("utf-8")       # valid utf-8 or raises
        checked += req.n_words
    assert checked == n_words
    print(f"parity ok: {checked} tokens bit-identical to the host"
          " normalise->segment->stem_batch pipeline")

    ayah = eng.result(rids[0]).analyses()[0]
    raw = ANKABUT.encode("utf-8")
    for root, _src, (b0, b1) in ayah[:6]:
        surface = raw[b0:b1].decode("utf-8")
        print(f"  {surface!r} -> root {root or '-'!r} bytes ({b0}, {b1})")


if __name__ == "__main__":
    main()
