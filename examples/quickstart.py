"""Quickstart: extract Arabic verb roots with the batched JAX stemmer.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import alphabet as ab
from repro.core import corpus, pyref, stemmer

SOURCE_NAMES = {
    pyref.SRC_NONE: "none",
    pyref.SRC_TRI: "trilateral",
    pyref.SRC_QUAD: "quadrilateral",
    pyref.SRC_RESTORED: "restored (hollow ا→و)",
    pyref.SRC_DEINFIX_TRI: "remove-infix (quad→tri)",
    pyref.SRC_DEINFIX_BI: "remove-infix (tri→bi)",
}


def main():
    words = [
        "أفاستسقيناكموها",  # the paper's flagship example -> سقي
        "سيلعبون",           # Table 3 example -> لعب
        "فتزحزحت",           # Fig 14 quadrilateral -> زحزح
        "قال",               # hollow verb -> قول via Restore-Original-Form
        "كاتب",              # form III -> كتب via Remove-Infix
        "يدرسون",            # plain present plural -> درس
        "والمعلمون",         # not a verb: expect no/incidental root
    ]
    roots = corpus.build_dictionary()
    dict_arrays = stemmer.RootDictArrays.from_rootdict(roots)
    enc = jnp.asarray(corpus.encode_corpus(words))

    extracted, sources = stemmer.stem_batch(enc, dict_arrays, backend="sorted")
    print(f"{'word':>18s} | {'root':>6s} | source")
    print("-" * 54)
    for w, r, s in zip(words, extracted, sources):
        root = ab.decode_word([int(c) for c in r])
        print(f"{w:>18s} | {root:>6s} | {SOURCE_NAMES[int(s)]}")


if __name__ == "__main__":
    main()
