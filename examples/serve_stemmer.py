"""Serve the stemmer megakernel behind the workload-agnostic Engine,
with a versioned dictionary hot swap landing mid-stream.

Word-batch requests coalesce into fixed [block_b, 16] tiles (one
megakernel launch per tick); after a few ticks a grown lexicon is
publish()ed and picked up by the next tile launch without an engine
restart. Every served word is then verified bit-identical to
core.stemmer.stem_batch under the dict version that served it — the
script exits non-zero on any mismatch, so CI runs it as a smoke test.

A second pass re-serves the same queue with one injected launch
failure and reads the recovery off ``Engine.events()`` — the
structured incident stream — instead of grepping workload counters.

  PYTHONPATH=src python examples/serve_stemmer.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import corpus, stemmer
from repro.serve import (DictStore, Engine, FaultInjector, FaultPlan,
                         FaultSpec, StemmerWorkload)

N_REQUESTS, WORDS_PER_REQ, BLOCK_B = 12, 40, 64


def main():
    d = corpus.build_dictionary(n_tri=800, n_quad=100, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    store = DictStore(arrays)
    eng = Engine(StemmerWorkload(store, block_b=BLOCK_B))

    words, _, _ = corpus.build_corpus(n_words=N_REQUESTS * WORDS_PER_REQ,
                                      seed=1)
    enc = corpus.encode_corpus(words)

    t0 = time.time()
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(N_REQUESTS)]
    for _ in range(3):           # a few ticks on dict v0 ...
        eng.step()
    v1 = store.publish(corpus.grow_root_arrays(arrays, 4096, seed=7))
    rep = eng.run_until_drained()  # ... the rest on the hot-swapped v1
    dt = time.time() - t0

    n_words = N_REQUESTS * WORDS_PER_REQ
    versions = np.concatenate([eng.result(r).dict_versions for r in rids])
    split = {int(v): int((versions == v).sum()) for v in np.unique(versions)}
    print(f"served {N_REQUESTS} requests / {n_words} words in {dt:.2f}s "
          f"({n_words / dt:.1f} Wps, {rep.ticks} ticks)")
    print(f"dict versions served: {split} (hot swap published v{v1} "
          f"mid-stream)")

    # bit-exact parity per served version against the batch stemmer
    checked = 0
    for rid in rids:
        req = eng.result(rid)
        assert req.done and req.dict_versions.shape == (req.n_words,)
        for v in np.unique(req.dict_versions):
            da = store.get(int(v)).arrays
            mask = req.dict_versions == v
            want_r, want_s = stemmer.stem_batch(jnp.asarray(req.words[mask]),
                                                da)
            assert np.array_equal(req.roots[mask], np.asarray(want_r)), (
                f"req {rid}: roots diverge from stem_batch under dict v{v}")
            assert np.array_equal(req.sources[mask], np.asarray(want_s)), (
                f"req {rid}: sources diverge from stem_batch under dict v{v}")
            checked += int(mask.sum())
    assert checked == n_words
    print(f"parity ok: {checked} words bit-identical to stem_batch under "
          f"their serving dict version")

    # -- faulted re-serve, observed through the structured event stream
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=1),)))
    eng2 = Engine(StemmerWorkload(DictStore(arrays), block_b=BLOCK_B,
                                  injector=inj))
    rids2 = [eng2.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
             for i in range(N_REQUESTS)]
    assert eng2.run_until_drained().drained
    retries = [e for e in eng2.events() if e.kind == "retry"]
    assert len(retries) == 1 and retries[0].data["attempt"] == 1
    assert not any(e.kind == "failure" for e in eng2.events())
    for rid in rids2:
        req = eng2.result(rid)
        want_r, _ = stemmer.stem_batch(jnp.asarray(req.words), arrays)
        assert np.array_equal(req.roots, np.asarray(want_r))
    print(f"fault recovery ok: retry observed via Engine.events()"
          f" (rids {retries[0].data['rids']}), drain bit-identical")


if __name__ == "__main__":
    main()
