"""Chaos smoke: every fault-injection site fired once, recovery verified.

Runs the seeded fault matrix end to end — one scenario per injector
site — and asserts the recovery invariant for each: a run that absorbs
the fault produces results bit-identical to the fault-free run (or, for
terminal faults, the correct structured FailureInfo), with no state
leaked into the serving engine, the dictionary store, or the index
checkpoint directory.

  dispatch    injected launch failure mid-ring -> retried, bit-identical
  retire      corrupted device readback -> checksum catch, redispatch,
              bit-identical
  publish     injected rejection between validation and the version
              bump -> store untouched, next publish lands, rollback
              restores the old lexicon as a new version
  checkpoint  torn index-partial write -> readback verify + rewrite,
              index bit-identical; plus a poison-pill request isolated
              by bisection quarantine while its tile-mates complete
  stall       wedged persistent descriptor ring -> watchdog abandons the
              launch, salvages the retired-prefix tiles, re-dispatches
              the rest down the megabatch path, bit-identical
  device_loss sharded launch loses a device -> the degradation ladder
              reshards onto fewer data devices (capped: the device does
              not come back), bit-identical
  journal     torn write-ahead-journal tail -> Engine.recover truncates
              to the last good record and replays the unfinished
              requests bit-identically (warm restart)

The script exits non-zero on any mismatch, so CI runs it as the chaos
step of the fault matrix.

  PYTHONPATH=src python examples/chaos_matrix.py
"""
import os

# the device_loss scenario reshards a 4-device mesh; force the host
# platform to expose 4 devices BEFORE jax initialises
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import tempfile
import time

import numpy as np

from repro.core import corpus, stemmer
from repro.index import builder
from repro.serve import (DegradationPolicy, DictStore, Engine,
                         FaultInjector, FaultPlan, FaultSpec,
                         InjectedFault, Journal, StemmerWorkload)

N_REQ = 8
WORDS_PER_REQ = 32
SEED = 20260809


def build_inputs():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=N_REQ * WORDS_PER_REQ, seed=1)
    return arrays, corpus.encode_corpus(words)


def drain(arrays, enc, *, injector=None, **kw):
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=2, injector=injector, **kw))
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    return eng, rids


def check_identical(eng, rids, baseline, skip=()):
    for i, rid in enumerate(rids):
        req = eng.result(rid)
        if i in skip:
            continue
        assert req.failure is None, f"req {rid}: {req.failure}"
        np.testing.assert_array_equal(req.roots, baseline[i])
        np.testing.assert_array_equal(req.sources, baseline[i + N_REQ])


def main():
    arrays, enc = build_inputs()
    eng0, rids0 = drain(arrays, enc)
    baseline = ([np.array(eng0.result(r).roots) for r in rids0]
                + [np.array(eng0.result(r).sources) for r in rids0])

    # --- site dispatch: launch failure retried ------------------------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=1),),
                                  seed=SEED))
    eng, rids = drain(arrays, enc, injector=inj)
    assert inj.fired == [("dispatch", "fail", 1)], inj.fired
    assert eng.workload.retries_total == 1
    check_identical(eng, rids, baseline)
    print("CHAOS_DISPATCH_OK")

    # --- site retire: corrupted readback caught by checksum -----------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("retire", at=0),),
                                  seed=SEED))
    eng, rids = drain(arrays, enc, injector=inj)
    assert eng.workload.checksum_failures == 1
    check_identical(eng, rids, baseline)
    print("CHAOS_RETIRE_OK")

    # --- site publish: two-phase publish rejected, then rollback ------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("publish", at=0),),
                                  seed=SEED))
    store = DictStore(arrays, keep_history=True, injector=inj)
    v0 = store.version
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    try:
        store.publish(grown)
        raise AssertionError("injected publish rejection did not fire")
    except InjectedFault:
        pass
    assert store.version == v0          # phase 2 never ran
    v1 = store.publish(grown)           # next publish lands cleanly
    v2 = store.rollback(v0)             # restore as a NEW version
    assert v2 > v1 > v0
    np.testing.assert_array_equal(
        np.asarray(store.acquire().handle.arrays.tri),
        np.asarray(store.get(v0).handle.arrays.tri))
    print("CHAOS_PUBLISH_OK")

    # --- site checkpoint: torn partial rewritten, index identical -----
    table = corpus.build_token_table(forms_per_root=6)

    def stream():
        return corpus.stream_corpus_words(9000, seed=3, chunk_words=4096,
                                          table=table)

    ref = builder.build_corpus_index(stream(), arrays, block_b=512,
                                     block_w=512)
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("checkpoint", at=1),),
                                  seed=SEED))
    with tempfile.TemporaryDirectory() as td:
        idx = builder.build_corpus_index(stream(), arrays,
                                         checkpoint_dir=td, block_b=512,
                                         block_w=512, injector=inj)
    assert inj.fired == [("checkpoint", "tear", 1)], inj.fired
    np.testing.assert_array_equal(np.asarray(idx.counts),
                                  np.asarray(ref.counts))
    np.testing.assert_array_equal(np.asarray(idx.docs),
                                  np.asarray(ref.docs))
    np.testing.assert_array_equal(np.asarray(idx.positions),
                                  np.asarray(ref.positions))
    print("CHAOS_CHECKPOINT_OK")

    # --- poison pill: bisection quarantine, tile-mates complete -------
    inj = FaultInjector(FaultPlan(poison_rids=frozenset({2}), seed=SEED))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=128,
                                 max_inflight=1, max_retries=1,
                                 injector=inj))
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(4)]
    assert eng.run_until_drained().drained
    assert eng.workload.quarantined == 1
    bad = eng.result(rids[2])
    assert bad.failure is not None and bad.failure.code == "quarantined"
    for i in (0, 1, 3):
        req = eng.result(rids[i])
        assert req.failure is None
        np.testing.assert_array_equal(req.roots, baseline[i])
    print("CHAOS_QUARANTINE_OK")

    # unknown sites are rejected at PLAN construction, not at fire time
    try:
        FaultSpec("gpu")
        raise AssertionError("unknown fault site accepted")
    except ValueError:
        pass

    # --- site stall: wedged persistent ring, watchdog salvage ---------
    inj = FaultInjector(FaultPlan(
        specs=(FaultSpec("stall", at=0, retired_tiles=2),), seed=SEED))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=1, persistent=True,
                                 megabatch_tiles=4, watchdog_s=0.05,
                                 injector=inj))
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    assert eng.workload.watchdog_stalls == 1
    stalls = [e for e in eng.events() if e.kind == "watchdog_stall"]
    assert len(stalls) == 1 and stalls[0].data["salvaged_words"] == 64
    check_identical(eng, rids, baseline)
    print("CHAOS_STALL_OK")

    # --- site device_loss: ladder reshards onto fewer devices ---------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("device_loss", at=0),),
                                  seed=SEED))
    pol = DegradationPolicy(down_after=1)
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=2, data_devices=4,
                                 injector=inj), policy=pol)
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    assert eng.workload.device_losses == 1
    assert any(t[2] == "device_loss" and t[1].startswith("devices-")
               for t in pol.transitions), pol.transitions
    eng.step()               # a requested mode lands at an empty-ring tick
    assert eng.workload.data_devices < 4      # resharded
    check_identical(eng, rids, baseline)
    print("CHAOS_DEVICE_LOSS_OK")

    # --- site journal: torn WAL tail, warm restart bit-identical ------
    with tempfile.TemporaryDirectory() as td:
        jp = os.path.join(td, "wal.jsonl")
        # tear the 9th append — the first RETIRE record (events 0..7 are
        # the admits) — so one served request must be re-served on replay
        inj = FaultInjector(FaultPlan(
            specs=(FaultSpec("journal", at=N_REQ),), seed=SEED))
        eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                     max_inflight=2),
                     journal=Journal(jp, fsync_every=1, injector=inj))
        rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
                for i in range(N_REQ)]
        for _ in range(2):
            eng.step()                        # serve a little, then "crash"
        done_before = {r: eng.result(r) for r in rids
                       if eng.result(r) is not None}
        eng2 = Engine.recover(jp, StemmerWorkload(DictStore(arrays),
                                                  block_b=32,
                                                  max_inflight=2))
        assert eng2.recovery.dropped_bytes > 0     # the tear was truncated
        assert eng2.run_until_drained().drained
        for i, r in enumerate(rids):
            req = done_before.get(r) or eng2.result(r)
            assert req is not None and req.failure is None
            np.testing.assert_array_equal(req.roots, baseline[i])
    print("CHAOS_JOURNAL_OK")


if __name__ == "__main__":
    t0 = time.time()
    main()
    print(f"CHAOS_MATRIX_OK ({time.time() - t0:.1f}s)")
