"""Chaos smoke: every fault-injection site fired once, recovery verified.

Runs the seeded fault matrix end to end — one scenario per injector
site — and asserts the recovery invariant for each: a run that absorbs
the fault produces results bit-identical to the fault-free run (or, for
terminal faults, the correct structured FailureInfo), with no state
leaked into the serving engine, the dictionary store, or the index
checkpoint directory.

  dispatch    injected launch failure mid-ring -> retried, bit-identical
  retire      corrupted device readback -> checksum catch, redispatch,
              bit-identical
  publish     injected rejection between validation and the version
              bump -> store untouched, next publish lands, rollback
              restores the old lexicon as a new version
  checkpoint  torn index-partial write -> readback verify + rewrite,
              index bit-identical; plus a poison-pill request isolated
              by bisection quarantine while its tile-mates complete

The script exits non-zero on any mismatch, so CI runs it as the chaos
step of the fault matrix.

  PYTHONPATH=src python examples/chaos_matrix.py
"""
import time

import numpy as np

from repro.core import corpus, stemmer
from repro.index import builder
from repro.serve import (DictStore, Engine, FaultInjector, FaultPlan,
                         FaultSpec, InjectedFault, StemmerWorkload)

N_REQ = 8
WORDS_PER_REQ = 32
SEED = 20260809


def build_inputs():
    d = corpus.build_dictionary(n_tri=400, n_quad=60, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    words, _, _ = corpus.build_corpus(n_words=N_REQ * WORDS_PER_REQ, seed=1)
    return arrays, corpus.encode_corpus(words)


def drain(arrays, enc, *, injector=None, **kw):
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=32,
                                 max_inflight=2, injector=injector, **kw))
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(N_REQ)]
    assert eng.run_until_drained().drained
    return eng, rids


def check_identical(eng, rids, baseline, skip=()):
    for i, rid in enumerate(rids):
        req = eng.result(rid)
        if i in skip:
            continue
        assert req.failure is None, f"req {rid}: {req.failure}"
        np.testing.assert_array_equal(req.roots, baseline[i])
        np.testing.assert_array_equal(req.sources, baseline[i + N_REQ])


def main():
    arrays, enc = build_inputs()
    eng0, rids0 = drain(arrays, enc)
    baseline = ([np.array(eng0.result(r).roots) for r in rids0]
                + [np.array(eng0.result(r).sources) for r in rids0])

    # --- site dispatch: launch failure retried ------------------------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("dispatch", at=1),),
                                  seed=SEED))
    eng, rids = drain(arrays, enc, injector=inj)
    assert inj.fired == [("dispatch", "fail", 1)], inj.fired
    assert eng.workload.retries_total == 1
    check_identical(eng, rids, baseline)
    print("CHAOS_DISPATCH_OK")

    # --- site retire: corrupted readback caught by checksum -----------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("retire", at=0),),
                                  seed=SEED))
    eng, rids = drain(arrays, enc, injector=inj)
    assert eng.workload.checksum_failures == 1
    check_identical(eng, rids, baseline)
    print("CHAOS_RETIRE_OK")

    # --- site publish: two-phase publish rejected, then rollback ------
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("publish", at=0),),
                                  seed=SEED))
    store = DictStore(arrays, keep_history=True, injector=inj)
    v0 = store.version
    grown = corpus.grow_root_arrays(arrays, 2048, seed=7)
    try:
        store.publish(grown)
        raise AssertionError("injected publish rejection did not fire")
    except InjectedFault:
        pass
    assert store.version == v0          # phase 2 never ran
    v1 = store.publish(grown)           # next publish lands cleanly
    v2 = store.rollback(v0)             # restore as a NEW version
    assert v2 > v1 > v0
    np.testing.assert_array_equal(
        np.asarray(store.acquire().handle.arrays.tri),
        np.asarray(store.get(v0).handle.arrays.tri))
    print("CHAOS_PUBLISH_OK")

    # --- site checkpoint: torn partial rewritten, index identical -----
    import tempfile

    table = corpus.build_token_table(forms_per_root=6)

    def stream():
        return corpus.stream_corpus_words(9000, seed=3, chunk_words=4096,
                                          table=table)

    ref = builder.build_corpus_index(stream(), arrays, block_b=512,
                                     block_w=512)
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("checkpoint", at=1),),
                                  seed=SEED))
    with tempfile.TemporaryDirectory() as td:
        idx = builder.build_corpus_index(stream(), arrays,
                                         checkpoint_dir=td, block_b=512,
                                         block_w=512, injector=inj)
    assert inj.fired == [("checkpoint", "tear", 1)], inj.fired
    np.testing.assert_array_equal(np.asarray(idx.counts),
                                  np.asarray(ref.counts))
    np.testing.assert_array_equal(np.asarray(idx.docs),
                                  np.asarray(ref.docs))
    np.testing.assert_array_equal(np.asarray(idx.positions),
                                  np.asarray(ref.positions))
    print("CHAOS_CHECKPOINT_OK")

    # --- poison pill: bisection quarantine, tile-mates complete -------
    inj = FaultInjector(FaultPlan(poison_rids=frozenset({2}), seed=SEED))
    eng = Engine(StemmerWorkload(DictStore(arrays), block_b=128,
                                 max_inflight=1, max_retries=1,
                                 injector=inj))
    rids = [eng.submit(enc[i * WORDS_PER_REQ:(i + 1) * WORDS_PER_REQ])
            for i in range(4)]
    assert eng.run_until_drained().drained
    assert eng.workload.quarantined == 1
    bad = eng.result(rids[2])
    assert bad.failure is not None and bad.failure.code == "quarantined"
    for i in (0, 1, 3):
        req = eng.result(rids[i])
        assert req.failure is None
        np.testing.assert_array_equal(req.roots, baseline[i])
    print("CHAOS_QUARANTINE_OK")


if __name__ == "__main__":
    t0 = time.time()
    main()
    print(f"CHAOS_MATRIX_OK ({time.time() - t0:.1f}s)")
