"""Build a root -> (doc, position) inverted index on device, verified.

A seeded synthetic corpus (core/corpus.py token-table stream) goes
through the chunked index driver — stemmer megakernel chained into the
postings-reduction kernel, no per-word host work — and the resulting
index is asserted bit-identical to the host numpy reference build
(stem_batch ids + stable argsort): same per-root counts, same postings,
same within-root order. A checkpointed rebuild resumed halfway must
reproduce the same index again. The script exits non-zero on any
mismatch, so CI runs it as a smoke test.

  PYTHONPATH=src python examples/index_corpus.py
"""
import itertools
import tempfile
import time

import numpy as np

from repro import index as ix
from repro.core import alphabet as ab
from repro.core import corpus, stemmer

N_WORDS = 16384
CHUNK = 4096
WORDS_PER_DOC = 256


def main():
    d = corpus.build_dictionary(n_tri=800, n_quad=100, seed=0)
    arrays = stemmer.RootDictArrays.from_rootdict(d)
    vocab = ix.build_vocab(arrays)
    table = corpus.build_token_table()

    def stream():
        return corpus.stream_corpus_words(
            N_WORDS, seed=17, chunk_words=CHUNK,
            words_per_doc=WORDS_PER_DOC, table=table)

    t0 = time.time()
    idx = ix.build_corpus_index(stream(), arrays, block_b=1024,
                                block_w=1024)
    dt = time.time() - t0
    print(f"indexed {N_WORDS} words / {N_WORDS // WORDS_PER_DOC} docs in"
          f" {dt:.2f}s ({N_WORDS / dt:.0f} Wps): {idx.n_postings} postings"
          f" over {int((idx.counts > 0).sum())} of {idx.n_roots} roots")

    # -- bit-exact parity vs the host numpy reference ----------------------
    chunks = list(stream())
    words = np.concatenate([c.words for c in chunks])
    docs = np.concatenate([c.doc_ids for c in chunks]).astype(np.int32)
    poss = np.concatenate([c.positions for c in chunks])
    ids = ix.host_root_ids(words, arrays, vocab)
    want_counts, want_docs, want_poss = ix.host_index(ids, docs, poss,
                                                      len(vocab))
    np.testing.assert_array_equal(idx.counts, want_counts)
    np.testing.assert_array_equal(idx.docs, want_docs)
    np.testing.assert_array_equal(idx.positions, want_poss)
    print(f"parity ok: {idx.n_postings} postings bit-identical to the"
          " host stem_batch -> stable-argsort reference")

    # -- checkpoint half the build, resume, same index ---------------------
    with tempfile.TemporaryDirectory() as ckpt:
        half = N_WORDS // CHUNK // 2
        ix.build_corpus_index(itertools.islice(stream(), half), arrays,
                              checkpoint_dir=ckpt, block_b=1024,
                              block_w=1024)
        idx2 = ix.build_corpus_index(stream(), arrays, checkpoint_dir=ckpt,
                                     resume=True, block_b=1024,
                                     block_w=1024)
    np.testing.assert_array_equal(idx2.counts, idx.counts)
    np.testing.assert_array_equal(idx2.docs, idx.docs)
    np.testing.assert_array_equal(idx2.positions, idx.positions)
    print(f"resume ok: index rebuilt from a {half}-chunk checkpoint is"
          " bit-identical")

    # -- the retrieval view: top roots and one postings lookup -------------
    top = np.argsort(idx.counts)[::-1][:5]
    for r in top:
        key = int(idx.root_keys[r])
        root = ab.decode_word(ab.unpack_key(key))
        print(f"  root {root!r}: {int(idx.counts[r])} postings, first at"
              f" doc {int(idx.docs[idx.offsets[r]])}"
              f" pos {int(idx.positions[idx.offsets[r]])}")
    dd, pp = idx.postings_for(int(idx.root_keys[top[0]]))
    assert len(dd) == int(idx.counts[top[0]])
    assert (np.diff(dd.astype(np.int64) * (max(pp) + 1) + pp) > 0).all(), \
        "postings not sorted by (doc, position)"
    print("lookup ok: postings_for returns sorted (doc, position) runs")


if __name__ == "__main__":
    main()
