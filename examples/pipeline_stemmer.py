"""The paper's pipelined processor across devices: the 5-stage stemmer on
a 5-device pipeline via shard_map + ppermute (dist/pipeline.py).

Needs >= 5 local devices; run with forced host devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=5 \
      PYTHONPATH=src python examples/pipeline_stemmer.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=5 "
        + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import alphabet as ab  # noqa: E402
from repro.core import corpus, stemmer  # noqa: E402
from repro.dist import pipeline  # noqa: E402


def main():
    assert len(jax.devices()) >= 5, "need 5 devices for the 5-stage pipeline"
    mesh = jax.make_mesh((5,), ("stage",))
    roots = corpus.build_dictionary(n_tri=800, n_quad=100)
    da = stemmer.RootDictArrays.from_rootdict(roots)

    words, truths, _ = corpus.build_corpus(n_words=64, seed=3)
    enc = jnp.asarray(corpus.encode_corpus(words))
    m, mb = 8, 8  # 8 microbatches of 8 words
    bundle = {
        "words": enc.reshape(m, mb, ab.MAXLEN),
        "keys": jnp.zeros((m, mb, 32), jnp.int32),
        "valid": jnp.zeros((m, mb, 32), jnp.int32),
        "root": jnp.zeros((m, mb, 4), jnp.int32),
        "source": jnp.zeros((m, mb), jnp.int32),
    }
    stage_fns = pipeline.stemmer_stage_fns(da)
    out = pipeline.pipeline_map(stage_fns, bundle, mesh, axis="stage")

    roots_flat = np.asarray(out["root"]).reshape(-1, 4)
    ok = 0
    for i, w in enumerate(words[:8]):
        root = ab.decode_word([int(c) for c in roots_flat[i]])
        print(f"{w:>16s} -> {root}")
    # verify against the single-device batch path
    ref_roots, ref_src = stemmer.stem_batch(enc, da)
    np.testing.assert_array_equal(roots_flat, np.asarray(ref_roots))
    np.testing.assert_array_equal(
        np.asarray(out["source"]).reshape(-1), np.asarray(ref_src))
    print("pipeline output == single-device batch output ✓")


if __name__ == "__main__":
    main()
