"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the morphologically-preprocessed Arabic character stream.

The data pipeline runs the paper's stemmer as a preprocessing operator
(root-id auxiliary labels), demonstrating the integration described in
DESIGN.md §4. ~100M params: 8 layers, d_model=768, vocab=64 (char-level).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import numpy as np

from repro import configs
from repro.configs import ModelConfig, RunConfig, ShapeConfig
from repro.core import alphabet as ab
from repro.data import pipeline as data_pipeline
from repro.train import loop


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="arabic-char-100m",
        n_layers=8,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=3072,
        vocab=ab.N_CODES + 1,
        tie_embeddings=False,
        rope_theta=10000.0,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(lm_100m(), d_model=args.d_model,
                              n_layers=args.layers,
                              d_ff=4 * args.d_model)
    from repro.models import model as model_mod
    from repro.models import params as pm

    n = pm.count_params(model_mod.model_spec(cfg))
    print(f"model: {cfg.name}  {n/1e6:.1f}M params")

    run = RunConfig(model=cfg,
                    shape=ShapeConfig("ex", args.seq, args.batch, "train"),
                    learning_rate=3e-3, lr_warmup=30, remat="none")

    base = data_pipeline.morph_lm_batches(batch_words=4096, seq=args.seq)

    def batched():
        while True:
            rows = [next(base) for _ in range(args.batch)]
            yield {
                "tokens": np.concatenate([r["tokens"] for r in rows]),
                "labels": np.concatenate([r["labels"] for r in rows]),
            }

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}", flush=True)

    result = loop.fit(cfg, run, batched(), steps=args.steps,
                      on_metrics=on_metrics)
    print(f"final loss {result.losses[-1]:.4f} "
          f"(start {result.losses[0]:.4f}) over {result.steps_run} steps")
    assert result.losses[-1] < result.losses[0], "LM failed to learn"


if __name__ == "__main__":
    main()
